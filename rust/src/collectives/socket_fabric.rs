//! [`SocketFabric`]: the ring [`Collective`] backend over **real
//! localhost TCP sockets**.
//!
//! This is the transport ROADMAP.md's "real NCCL/CGX socket backend"
//! item asked for: the exact [`EncodedTensor::to_bytes_into`] octets
//! that [`super::AsyncFabric`] moves over in-process channels are put
//! on genuine kernel sockets instead, with **length-prefixed framing**
//! (an 8-byte little-endian byte count before each message). The ring
//! schedule, per-rank scratch pools, command protocol, per-rank rng
//! streams, cross-check sampling and shutdown-on-drop lifecycle are
//! all shared with the async backend (the `ring` module); only the
//! [`RingTransport`] implementation differs, so everything the
//! differential harness pins — FP32 bit-exactness, codec-resolution
//! error bounds, analytic ring byte counts — carries over unchanged.
//! That includes the non-blocking `start_all_gather` /
//! `start_reduce_scatter` submission path: the same runtime commands,
//! dispatched without blocking and drained in `wait()`, with TCP
//! frames in flight while the caller computes.
//!
//! # Wire protocol
//!
//! One TCP connection per directed ring link, established **once at
//! fabric construction**: rank `r` binds a listener, connects to rank
//! `(r+1) % P`'s listener, and accepts the connection from rank
//! `(r-1) % P` (so a 2-rank ring uses two separate connections, one
//! per direction — exactly the two channel inboxes of the async
//! backend). Each hop writes `[len: u64 LE][len octets]` and reads the
//! same; the octets are a serialized [`EncodedTensor`] message,
//! validated by [`EncodedTensor::view_bytes`] on receipt. `TCP_NODELAY`
//! is set on every stream (frames are latency-sensitive and already
//! batched).
//!
//! # Deadlock freedom
//!
//! In a ring, every rank sends and receives *simultaneously*; a
//! transport that fully sends before it reads deadlocks as soon as
//! frames outgrow the kernel's socket buffers (all P writers block,
//! nobody reads). The exchange therefore runs both streams
//! **non-blocking** and pumps whichever direction can make progress,
//! yielding only when neither can — full-duplex, bounded memory, no
//! ordering assumption between peers. A peer that dies closes its
//! sockets; the pump sees EOF / `ECONNRESET` / `EPIPE` and fails the
//! hop with a typed [`RingError`] instead of blocking (a generous
//! stall limit backstops pathological cases), which the runtime turns
//! into one clean per-rank diagnosis — see `tests/fabric_failures.rs`.
//!
//! # Environment sensitivity
//!
//! Sandboxes sometimes forbid even loopback TCP. Construction is
//! therefore fallible ([`SocketFabric::new`] returns `Result`), and
//! [`loopback_available`] lets tests and benches skip the backend
//! **loudly** (a logged SKIP line, never a silent pass) when the
//! environment cannot support it.

use super::fabric::{check_inputs, Collective, PendingCollective};
use super::ledger::TrafficLedger;
use super::ring::{
    runtime_all_gather_into, runtime_all_reduce, runtime_reduce_scatter, submit_all_gather_into,
    submit_reduce_scatter_into, world1_reduce_scatter, FabricRuntime, RingError, RingTransport,
};
use crate::quant::{Codec, EncodedTensor};
use crate::sim::Topology;
use crate::util::Pcg64;
use anyhow::{bail, ensure, Context, Result};
use std::cell::Cell;
use std::io::{ErrorKind, Read, Write};
use std::net::{IpAddr, Ipv4Addr, SocketAddr, TcpListener, TcpStream};
use std::time::{Duration, Instant};

pub use super::async_fabric::DEFAULT_CHECK_EVERY;

/// Length prefix: one little-endian u64 byte count per frame.
const FRAME_HEADER_BYTES: usize = 8;

/// Upper bound on an accepted frame. A corrupt length prefix must
/// produce a clean error, not a multi-gigabyte allocation.
const MAX_FRAME_BYTES: u64 = 1 << 30;

/// If neither direction of an exchange makes progress for this long,
/// the hop fails instead of spinning forever. Generous: localhost
/// frames complete in microseconds; only a wedged peer gets here.
const STALL_LIMIT: Duration = Duration::from_secs(60);

/// Deadline for each construction-time connect/accept.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(5);

/// Can this environment do loopback TCP at all? Binds an ephemeral
/// listener and completes one real connect/accept round trip — the
/// full set of operations fabric construction needs.
pub fn loopback_available() -> bool {
    fn probe() -> std::io::Result<()> {
        let l = TcpListener::bind((Ipv4Addr::LOCALHOST, 0))?;
        let addr = l.local_addr()?;
        let _c = TcpStream::connect(addr)?;
        let _s = l.accept()?;
        Ok(())
    }
    probe().is_ok()
}

/// One rank's two directed TCP connections: `out` to the ring
/// successor, `inp` from the ring predecessor, plus the receive
/// staging buffer that gets swapped with the caller's buffer after
/// each completed exchange (so both sides recycle their allocations).
pub(crate) struct SocketLink {
    out: TcpStream,
    inp: TcpStream,
    in_buf: Vec<u8>,
    /// Per-link stall backstop: if neither direction progresses for
    /// this long the hop fails. The in-process fabric keeps the
    /// generous [`STALL_LIMIT`]; the elastic fabric sets a short limit
    /// so survivors of a dead peer fault within the recovery window
    /// instead of a minute later.
    stall: Duration,
}

impl SocketLink {
    /// A link with the default (generous) stall backstop. Streams must
    /// already be non-blocking.
    pub(crate) fn new(out: TcpStream, inp: TcpStream) -> Self {
        Self::with_stall(out, inp, STALL_LIMIT)
    }

    /// A link with an explicit stall backstop (the elastic fabric's
    /// failure-detection knob).
    pub(crate) fn with_stall(out: TcpStream, inp: TcpStream, stall: Duration) -> Self {
        SocketLink { out, inp, in_buf: Vec::new(), stall }
    }
}

/// Build one directed ring link for the elastic fabric: connect to the
/// successor's listener, accept the predecessor's connection on our
/// own (already-bound) listener, and configure both streams. The
/// caller advertised `listener`'s address through the rendezvous, so
/// every member runs this concurrently and the connects complete
/// against the listen backlogs.
pub(crate) fn elastic_link(
    listener: &TcpListener,
    successor: SocketAddr,
    stall: Duration,
) -> Result<SocketLink> {
    let out = TcpStream::connect_timeout(&successor, CONNECT_TIMEOUT)
        .with_context(|| format!("elastic wire: connect to ring successor at {successor}"))?;
    let inp = accept_with_deadline(listener, CONNECT_TIMEOUT)
        .context("elastic wire: accept from ring predecessor")?;
    for s in [&out, &inp] {
        s.set_nodelay(true).context("elastic wire: set_nodelay")?;
        s.set_nonblocking(true).context("elastic wire: set_nonblocking")?;
    }
    Ok(SocketLink::with_stall(out, inp, stall))
}

/// Write as much of `[header][payload]` as the kernel will take
/// without blocking. `pos` is the combined progress cursor. Returns
/// whether any bytes moved.
// lint:zero-alloc
fn pump_write(
    stream: &mut TcpStream,
    header: &[u8; FRAME_HEADER_BYTES],
    payload: &[u8],
    pos: &mut usize,
) -> Result<bool, RingError> {
    let total = FRAME_HEADER_BYTES + payload.len();
    let mut progressed = false;
    while *pos < total {
        let chunk: &[u8] = if *pos < FRAME_HEADER_BYTES {
            &header[*pos..]
        } else {
            &payload[*pos - FRAME_HEADER_BYTES..]
        };
        match stream.write(chunk) {
            Ok(0) => return Err(RingError::successor("socket refused bytes mid-frame")),
            Ok(k) => {
                *pos += k;
                progressed = true;
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(RingError::successor(format!("write failed: {e}"))), // lint:cold
        }
    }
    Ok(progressed)
}

/// Incoming-frame progress: the length prefix accumulates in `header`
/// until complete, then `total` is validated and fixed and the payload
/// accumulates in the staging buffer.
struct InProgress {
    header: [u8; FRAME_HEADER_BYTES],
    pos: usize,
    total: Option<usize>,
}

impl InProgress {
    fn new() -> Self {
        InProgress { header: [0; FRAME_HEADER_BYTES], pos: 0, total: None }
    }

    fn done(&self) -> bool {
        self.total.is_some_and(|t| self.pos >= t)
    }
}

/// Read as much of the incoming frame as is available without
/// blocking. Returns whether any bytes moved.
// lint:zero-alloc
fn pump_read(
    stream: &mut TcpStream,
    st: &mut InProgress,
    buf: &mut Vec<u8>,
) -> Result<bool, RingError> {
    let mut progressed = false;
    loop {
        match st.total {
            None => match stream.read(&mut st.header[st.pos..]) {
                Ok(0) => {
                    return Err(RingError::predecessor(
                        "connection closed before a full length prefix",
                    ))
                }
                Ok(k) => {
                    st.pos += k;
                    progressed = true;
                    if st.pos == FRAME_HEADER_BYTES {
                        let len = u64::from_le_bytes(st.header);
                        if len > MAX_FRAME_BYTES {
                            // lint:cold
                            return Err(RingError::corrupt(format!(
                                "frame length {len} exceeds the {MAX_FRAME_BYTES}-byte cap"
                            )));
                        }
                        // Size the staging buffer without zero-filling
                        // bytes the reads below overwrite anyway:
                        // growing fills only the new tail, and every
                        // byte in [0, len) is read before `done()`.
                        let len = len as usize;
                        if buf.len() < len {
                            buf.resize(len, 0);
                        } else {
                            buf.truncate(len);
                        }
                        st.total = Some(len);
                        st.pos = 0;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => {
                    // lint:cold
                    return Err(RingError::predecessor(format!("read failed: {e}")));
                }
            },
            Some(total) => {
                if st.pos >= total {
                    break;
                }
                match stream.read(&mut buf[st.pos..total]) {
                    Ok(0) => {
                        // lint:cold
                        return Err(RingError::predecessor(format!(
                            "connection closed mid-frame ({} of {total} payload bytes)",
                            st.pos
                        )));
                    }
                    Ok(k) => {
                        st.pos += k;
                        progressed = true;
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(e) => {
                        // lint:cold
                        return Err(RingError::predecessor(format!("read failed: {e}")));
                    }
                }
            }
        }
    }
    Ok(progressed)
}

impl RingTransport for SocketLink {
    /// Full-duplex frame exchange: write `buf` to the successor while
    /// reading the predecessor's frame, then swap the received frame
    /// into `buf`. Both streams are non-blocking; see the module docs
    /// for why the interleaving is what makes the ring deadlock-free.
    // lint:zero-alloc
    fn exchange(&mut self, buf: &mut Vec<u8>) -> Result<(), RingError> {
        let header = (buf.len() as u64).to_le_bytes();
        let out_total = FRAME_HEADER_BYTES + buf.len();
        let mut out_pos = 0usize;
        let mut st = InProgress::new();
        let mut last_progress = Instant::now();
        let mut idle_spins = 0u32;
        loop {
            let wrote = pump_write(&mut self.out, &header, buf, &mut out_pos)?;
            let read = pump_read(&mut self.inp, &mut st, &mut self.in_buf)?;
            if out_pos == out_total && st.done() {
                break;
            }
            if wrote || read {
                last_progress = Instant::now();
                idle_spins = 0;
            } else {
                if last_progress.elapsed() > self.stall {
                    // lint:cold
                    return Err(RingError::stalled(format!(
                        "no progress for {:.1}s (sent {out_pos}/{out_total} bytes)",
                        self.stall.as_secs_f64()
                    )));
                }
                // Spin briefly (a peer mid-hop answers in microseconds),
                // then back off to a short sleep so a rank waiting on a
                // slow neighbor — or, in the failure path, on a wedged
                // one — does not peg a core for the whole stall window.
                idle_spins += 1;
                if idle_spins < 64 {
                    std::thread::yield_now();
                } else {
                    std::thread::sleep(Duration::from_micros(100));
                }
            }
        }
        std::mem::swap(buf, &mut self.in_buf);
        Ok(())
    }

    /// Receive-only half of the exchange, for the fault injector's
    /// dropped-frame semantics: pump the incoming stream under the same
    /// stall backstop, send nothing.
    // lint:zero-alloc
    fn recv_only(&mut self, buf: &mut Vec<u8>) -> Result<(), RingError> {
        let mut st = InProgress::new();
        let mut last_progress = Instant::now();
        let mut idle_spins = 0u32;
        while !st.done() {
            if pump_read(&mut self.inp, &mut st, &mut self.in_buf)? {
                last_progress = Instant::now();
                idle_spins = 0;
            } else {
                if last_progress.elapsed() > self.stall {
                    // lint:cold
                    return Err(RingError::stalled(format!(
                        "no incoming progress for {:.1}s (receive-only)",
                        self.stall.as_secs_f64()
                    )));
                }
                idle_spins += 1;
                if idle_spins < 64 {
                    std::thread::yield_now();
                } else {
                    std::thread::sleep(Duration::from_micros(100));
                }
            }
        }
        std::mem::swap(buf, &mut self.in_buf);
        Ok(())
    }
}

/// Accept one connection, polling against a deadline so a sandbox that
/// silently drops loopback packets produces an error instead of a
/// hang.
fn accept_with_deadline(listener: &TcpListener, limit: Duration) -> Result<TcpStream> {
    listener.set_nonblocking(true).context("listener set_nonblocking")?;
    let deadline = Instant::now() + limit;
    loop {
        match listener.accept() {
            Ok((s, _)) => return Ok(s),
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                if Instant::now() > deadline {
                    bail!("no inbound connection within {}s", limit.as_secs());
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
}

/// Establish the P directed TCP connections of a ring on `addr`.
/// With `base_port == 0` every listener gets a kernel-assigned
/// ephemeral port (collision-free, the default); otherwise rank `r`
/// listens on `base_port + r` (for firewalled setups that need pinned
/// ports). Connections are made once, here; the links live until the
/// fabric drops.
fn ring_links(addr: IpAddr, base_port: u16, p: usize, stall: Duration) -> Result<Vec<SocketLink>> {
    let mut listeners = Vec::with_capacity(p);
    for r in 0..p {
        let port = if base_port == 0 {
            0
        } else {
            base_port.checked_add(r as u16).with_context(|| {
                format!("socket fabric: base port {base_port} + rank {r} overflows u16")
            })?
        };
        let l = TcpListener::bind(SocketAddr::new(addr, port)).map_err(|e| {
            // A configured port that some other process already holds
            // used to surface as an opaque connect-timeout on a peer;
            // name the real cause instead.
            if e.kind() == ErrorKind::AddrInUse && port != 0 {
                anyhow::anyhow!(
                    "socket fabric: rank-{r} port {addr}:{port} is already bound by another \
                     process — pick a different --fabric-port range, or 0 for ephemeral ports"
                )
            } else {
                anyhow::Error::new(e)
                    .context(format!("socket fabric: bind rank-{r} listener on {addr}:{port}"))
            }
        })?;
        listeners.push(l);
    }
    let mut addrs = Vec::with_capacity(p);
    for l in &listeners {
        addrs.push(l.local_addr().context("socket fabric: listener local_addr")?);
    }
    // Connect every rank to its successor first (the kernel completes
    // the handshakes against the listen backlog), then accept the
    // predecessor's connection on each listener.
    let mut outs = Vec::with_capacity(p);
    for r in 0..p {
        let peer = addrs[(r + 1) % p];
        let s = TcpStream::connect_timeout(&peer, CONNECT_TIMEOUT)
            .with_context(|| format!("socket fabric: rank {r} connect to successor at {peer}"))?;
        outs.push(s);
    }
    let mut ins = Vec::with_capacity(p);
    for (r, l) in listeners.iter().enumerate() {
        let s = accept_with_deadline(l, CONNECT_TIMEOUT)
            .with_context(|| format!("socket fabric: rank {r} accept from predecessor"))?;
        ins.push(s);
    }
    let mut links = Vec::with_capacity(p);
    for (out, inp) in outs.into_iter().zip(ins) {
        for s in [&out, &inp] {
            s.set_nodelay(true).context("socket fabric: set_nodelay")?;
            s.set_nonblocking(true).context("socket fabric: set_nonblocking")?;
        }
        links.push(SocketLink::with_stall(out, inp, stall));
    }
    Ok(links)
}

/// Ring collectives over real localhost TCP connections, established
/// once at construction and owned by a persistent per-rank runtime
/// (shutdown + join on drop). Always persistent — there is no
/// spawn-per-call mode; reconnecting P sockets per collective would
/// benchmark the kernel's connect path, not the transport.
pub struct SocketFabric {
    topo: Topology,
    check_every: u64,
    calls: Cell<u64>,
    runtime: Option<FabricRuntime>,
}

impl std::fmt::Debug for SocketFabric {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SocketFabric")
            .field("topo", &self.topo)
            .field("check_every", &self.check_every)
            .finish()
    }
}

impl SocketFabric {
    /// Loopback TCP on kernel-assigned ephemeral ports, default
    /// cross-check sampling. Fails if the environment forbids loopback
    /// sockets — see [`loopback_available`] for a cheap probe.
    pub fn new(topo: Topology) -> Result<Self> {
        let local = IpAddr::V4(Ipv4Addr::LOCALHOST);
        Self::with_options(topo, local, 0, DEFAULT_CHECK_EVERY, STALL_LIMIT)
    }

    /// Full control: bind address, base port (rank `r` listens on
    /// `base_port + r`; 0 = ephemeral), the release-build gather
    /// cross-check sampling period (every Nth call; 0 = never — debug
    /// builds always check), and the per-hop stall deadline (no
    /// progress in either direction for this long fails the hop;
    /// `--fabric-stall-ms` plumbs it from the CLI).
    pub fn with_options(
        topo: Topology,
        addr: IpAddr,
        base_port: u16,
        check_every: u64,
        stall: Duration,
    ) -> Result<Self> {
        Self::build(topo, addr, base_port, check_every, stall, None)
    }

    /// A fabric with a [`crate::faults::FaultPlan`] armed on its TCP
    /// ring links — chaos-harness and failure-test use only; the
    /// normal constructors carry no injection hook.
    pub fn with_fault_plan(
        topo: Topology,
        addr: IpAddr,
        base_port: u16,
        check_every: u64,
        stall: Duration,
        plan: &crate::faults::FaultPlan,
    ) -> Result<Self> {
        ensure!(topo.world() > 1, "fault injection needs a ring (world > 1)");
        Self::build(topo, addr, base_port, check_every, stall, Some(plan))
    }

    fn build(
        topo: Topology,
        addr: IpAddr,
        base_port: u16,
        check_every: u64,
        stall: Duration,
        plan: Option<&crate::faults::FaultPlan>,
    ) -> Result<Self> {
        let runtime = if topo.world() > 1 {
            let links: Vec<Box<dyn RingTransport>> =
                ring_links(addr, base_port, topo.world(), stall)?
                    .into_iter()
                    .map(|l| Box::new(l) as Box<dyn RingTransport>)
                    .collect();
            let links = match plan {
                Some(plan) => crate::faults::arm_links(links, plan),
                None => links,
            };
            Some(FabricRuntime::spawn(topo, links))
        } else {
            // World 1 never touches a wire: the collectives
            // short-circuit, so no sockets are opened and construction
            // succeeds even where loopback is forbidden.
            None
        };
        Ok(SocketFabric { topo, check_every, calls: Cell::new(0), runtime })
    }

    /// Should this call run the all-ranks gather cross-check? Always in
    /// debug builds; 1-in-`check_every` calls in release.
    fn check_due(&self) -> bool {
        let k = self.calls.get();
        self.calls.set(k.wrapping_add(1));
        cfg!(debug_assertions) || (self.check_every > 0 && k % self.check_every == 0)
    }

    /// Test hook: make worker `rank` exit as if its process died. See
    /// `tests/fabric_failures.rs`.
    #[doc(hidden)]
    pub fn fail_rank_for_test(&self, rank: usize) {
        self.rt().kill_worker(rank);
    }

    /// The persistent runtime behind every world > 1 dispatch. Callers
    /// below reach this only after their `world == 1` short-circuit.
    fn rt(&self) -> &FabricRuntime {
        // lint:allow(panic-path): `build` spawns the runtime whenever
        // world > 1, so a miss here is an internal invariant breach.
        self.runtime.as_ref().expect("world > 1 spawns the socket runtime")
    }
}

impl Collective for SocketFabric {
    fn name(&self) -> &'static str {
        "socket"
    }

    fn topo(&self) -> Topology {
        self.topo
    }

    fn all_gather(&self, shards: &[EncodedTensor], ledger: &mut TrafficLedger) -> Vec<f32> {
        let mut out = Vec::new();
        self.all_gather_into(shards, &mut out, ledger);
        out
    }

    /// Ring AllGather into a caller-owned output buffer; every hop's
    /// octets cross a real TCP connection.
    fn all_gather_into(
        &self,
        shards: &[EncodedTensor],
        out: &mut Vec<f32>,
        ledger: &mut TrafficLedger,
    ) {
        let p = self.topo.world();
        // lint:allow(panic-path): API precondition on the caller's shard count, checked
        // before any wire traffic — a shape bug, not a link fault.
        assert_eq!(shards.len(), p, "one shard per rank");
        if p == 1 {
            shards[0].decode(out);
            return;
        }
        let check = self.check_due();
        let rt = self.rt();
        runtime_all_gather_into(rt, "socket", shards, out, ledger, check);
    }

    /// Ring ReduceScatter (reduce-and-forward over TCP).
    fn reduce_scatter(
        &self,
        inputs: &[Vec<f32>],
        codec: &dyn Codec,
        rng: &mut Pcg64,
        ledger: &mut TrafficLedger,
    ) -> Vec<Vec<f32>> {
        let topo = self.topo;
        let n_elems = check_inputs(&topo, inputs);
        if topo.world() == 1 {
            return world1_reduce_scatter(&inputs[0], codec, rng);
        }
        let base = rng.next_u64();
        let rt = self.rt();
        runtime_reduce_scatter(rt, "socket", inputs, codec, base, n_elems, ledger)
    }

    /// Fused ring AllReduce (one runtime command; see the `ring`
    /// module).
    fn all_reduce(
        &self,
        inputs: &[Vec<f32>],
        codec_rs: &dyn Codec,
        codec_ag: &dyn Codec,
        rng: &mut Pcg64,
        ledger: &mut TrafficLedger,
    ) -> Vec<f32> {
        let topo = self.topo;
        let n_elems = check_inputs(&topo, inputs);
        if topo.world() == 1 {
            // Match the trait's default composition exactly (shared
            // caller rng stream — see `world1_reduce_scatter`).
            let shards = self.reduce_scatter(inputs, codec_rs, rng, ledger);
            let encoded: Vec<EncodedTensor> =
                shards.iter().map(|s| codec_ag.encode(s, rng)).collect();
            return self.all_gather(&encoded, ledger);
        }
        let base = rng.next_u64();
        let check = self.check_due();
        let rt = self.rt();
        runtime_all_reduce(rt, "socket", inputs, codec_rs, codec_ag, base, n_elems, check, ledger)
    }

    /// Non-blocking ring AllGather over TCP: the frames are in flight
    /// while the caller computes; `wait()` drains all ranks.
    fn start_all_gather<'a>(
        &'a self,
        shards: &'a [EncodedTensor],
        out: &'a mut Vec<f32>,
        ledger: &'a mut TrafficLedger,
    ) -> PendingCollective<'a> {
        let p = self.topo.world();
        // lint:allow(panic-path): API precondition on the caller's shard count, checked
        // before any wire traffic — a shape bug, not a link fault.
        assert_eq!(shards.len(), p, "one shard per rank");
        if p == 1 {
            shards[0].decode(out);
            return PendingCollective::ready();
        }
        let check = self.check_due();
        let rt = self.rt();
        PendingCollective::in_flight(submit_all_gather_into(rt, "socket", shards, out, ledger, check))
    }

    /// Non-blocking ring ReduceScatter over TCP into the caller's
    /// reusable `outs` pool; the rng base is drawn at submit time.
    fn start_reduce_scatter<'a>(
        &'a self,
        inputs: &'a [Vec<f32>],
        codec: &'a dyn Codec,
        rng: &mut Pcg64,
        outs: &'a mut Vec<Vec<f32>>,
        ledger: &'a mut TrafficLedger,
    ) -> PendingCollective<'a> {
        let topo = self.topo;
        let n_elems = check_inputs(&topo, inputs);
        if topo.world() == 1 {
            *outs = world1_reduce_scatter(&inputs[0], codec, rng);
            return PendingCollective::ready();
        }
        let base = rng.next_u64();
        let rt = self.rt();
        PendingCollective::in_flight(submit_reduce_scatter_into(
            rt, "socket", inputs, codec, base, n_elems, outs, ledger,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::ring::RingFault;
    use crate::collectives::LockstepFabric;
    use crate::quant::{Fp32Codec, MinMaxCodec};
    use crate::util::stats::rel_l2_err;

    fn skip_no_loopback() -> bool {
        if loopback_available() {
            false
        } else {
            eprintln!("SKIP: loopback TCP unavailable in this sandbox; socket test not run");
            true
        }
    }

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::seeded(seed);
        let mut v = vec![0.0; n];
        rng.fill_normal(&mut v, 1.0);
        v
    }

    /// A connected (client, server) loopback stream pair.
    fn tcp_pair() -> std::io::Result<(TcpStream, TcpStream)> {
        let l = TcpListener::bind((Ipv4Addr::LOCALHOST, 0))?;
        let addr = l.local_addr()?;
        let c = TcpStream::connect(addr)?;
        let (s, _) = l.accept()?;
        Ok((c, s))
    }

    /// A SocketLink whose incoming side is fed by the returned writer
    /// stream (the outgoing side goes to a kept-alive sink).
    fn crafted_link() -> std::io::Result<(SocketLink, TcpStream, TcpStream)> {
        let (writer, inp) = tcp_pair()?;
        let (out, sink) = tcp_pair()?;
        inp.set_nonblocking(true)?;
        out.set_nonblocking(true)?;
        Ok((SocketLink::new(out, inp), writer, sink))
    }

    #[test]
    fn socket_all_gather_matches_lockstep_bitwise() {
        if skip_no_loopback() {
            return;
        }
        let topo = Topology::new(2, 3);
        let n = 1037;
        let full = rand_vec(n, 1);
        let mut rng = Pcg64::seeded(2);
        let codec = MinMaxCodec::new(8, 64, true);
        let shards: Vec<EncodedTensor> = (0..topo.world())
            .map(|r| codec.encode(&full[topo.shard_range(n, r)], &mut rng))
            .collect();
        let fabric = SocketFabric::new(topo).expect("construct socket fabric");
        let mut ls = TrafficLedger::new();
        let s = fabric.all_gather(&shards, &mut ls);
        let mut ll = TrafficLedger::new();
        let l = LockstepFabric::new(topo).all_gather(&shards, &mut ll);
        assert_eq!(s, l, "socket decode differs from lockstep decode");
        assert_eq!(s.len(), n);
        // every rank sends P-1 messages, the ledger counts payload
        // octets only (the 8-byte frame prefix is transport framing,
        // not message bytes)
        assert_eq!(ls.messages, topo.world() * (topo.world() - 1));
    }

    #[test]
    fn socket_reduce_scatter_fp32_exact_sum() {
        if skip_no_loopback() {
            return;
        }
        let topo = Topology::new(2, 2);
        let n = 50;
        let inputs: Vec<Vec<f32>> = (0..4).map(|r| rand_vec(n, 10 + r as u64)).collect();
        let mut expect = vec![0.0f32; n];
        for i in &inputs {
            for (a, &x) in expect.iter_mut().zip(i) {
                *a += x;
            }
        }
        let fabric = SocketFabric::new(topo).expect("construct socket fabric");
        let mut ledger = TrafficLedger::new();
        let outs = fabric.reduce_scatter(&inputs, &Fp32Codec, &mut Pcg64::seeded(1), &mut ledger);
        for (r, shard) in outs.iter().enumerate() {
            let range = topo.shard_range(n, r);
            assert_eq!(shard.len(), range.len());
            for (a, &b) in shard.iter().zip(&expect[range]) {
                assert!((a - b).abs() < 1e-4, "rank {r}: {a} vs {b}");
            }
        }
        assert_eq!(ledger.messages, 12);
    }

    #[test]
    fn socket_world1_needs_no_sockets() {
        // World 1 never opens a connection, so this runs even where
        // loopback is forbidden — and must match the other backends
        // bit-for-bit (shared caller rng stream).
        let topo = Topology::new(1, 1);
        let input = vec![rand_vec(257, 5)];
        let fabric = SocketFabric::new(topo).expect("world-1 construction is socket-free");
        let mut ledger = TrafficLedger::new();
        let shard = vec![EncodedTensor::fp32(&input[0])];
        assert_eq!(fabric.all_gather(&shard, &mut ledger), input[0]);
        let codec = MinMaxCodec::new(8, 64, true);
        let outs = fabric.reduce_scatter(&input, &codec, &mut Pcg64::seeded(3), &mut ledger);
        let mut ll = TrafficLedger::new();
        let lock = LockstepFabric::new(topo).reduce_scatter(
            &input,
            &codec,
            &mut Pcg64::seeded(3),
            &mut ll,
        );
        assert_eq!(outs, lock, "world-1 numerics must not depend on the fabric");
        assert!(rel_l2_err(&outs[0], &input[0]) < 0.02);
        assert_eq!(ledger.total_bytes(), 0);
    }

    #[test]
    fn socket_frame_oversize_length_is_corrupt_not_oom() {
        if skip_no_loopback() {
            return;
        }
        let (mut link, mut writer, _sink) = crafted_link().unwrap();
        writer.write_all(&u64::MAX.to_le_bytes()).unwrap();
        let mut buf = vec![1u8, 2, 3];
        let err = link.exchange(&mut buf).expect_err("oversize frame must fail");
        assert_eq!(err.fault, RingFault::CorruptFrame);
        assert!(err.detail.contains("cap"), "detail should name the cap: {}", err.detail);
    }

    #[test]
    fn socket_frame_truncated_is_peer_hangup_not_panic() {
        if skip_no_loopback() {
            return;
        }
        let (mut link, mut writer, _sink) = crafted_link().unwrap();
        writer.write_all(&100u64.to_le_bytes()).unwrap();
        writer.write_all(&[7u8; 10]).unwrap();
        drop(writer); // close mid-frame: 10 of 100 payload bytes sent
        let mut buf = vec![0u8; 4];
        let err = link.exchange(&mut buf).expect_err("truncated frame must fail");
        assert_eq!(err.fault, RingFault::PredecessorGone);
        assert!(err.detail.contains("mid-frame"), "{}", err.detail);
    }

    #[test]
    fn socket_exchange_round_trips_and_recycles_buffers() {
        if skip_no_loopback() {
            return;
        }
        // Two crafted links wired head-to-head: a's out feeds b's inp
        // and vice versa — a genuine 2-ring, driven from two threads.
        let (a_out, b_inp) = tcp_pair().unwrap();
        let (b_out, a_inp) = tcp_pair().unwrap();
        for s in [&a_out, &a_inp, &b_out, &b_inp] {
            s.set_nonblocking(true).unwrap();
        }
        let mut a = SocketLink::new(a_out, a_inp);
        let mut b = SocketLink::new(b_out, b_inp);
        // Frames big enough to overflow any default socket buffer:
        // passes only because exchange is full-duplex.
        let a_frame = vec![0xAAu8; 8 << 20];
        let b_frame = vec![0xBBu8; 8 << 20];
        let (a_frame_c, b_frame_c) = (a_frame.clone(), b_frame.clone());
        let t = std::thread::spawn(move || {
            let mut buf = b_frame_c;
            b.exchange(&mut buf).expect("b exchange");
            buf
        });
        let mut buf = a_frame_c;
        a.exchange(&mut buf).expect("a exchange");
        let b_got = t.join().expect("b thread");
        assert_eq!(buf, b_frame, "a must receive b's frame");
        assert_eq!(b_got, a_frame, "b must receive a's frame");
    }

    #[test]
    fn overlap_socket_start_wait_matches_blocking() {
        if skip_no_loopback() {
            return;
        }
        let topo = Topology::new(2, 2);
        let n = 1037;
        let full = rand_vec(n, 61);
        let inputs: Vec<Vec<f32>> =
            (0..topo.world()).map(|r| rand_vec(n, 70 + r as u64)).collect();
        let codec = MinMaxCodec::new(8, 128, true);
        let mut enc_rng = Pcg64::seeded(62);
        let shards: Vec<EncodedTensor> = (0..topo.world())
            .map(|r| codec.encode(&full[topo.shard_range(n, r)], &mut enc_rng))
            .collect();
        let blocking = SocketFabric::new(topo).expect("construct socket fabric");
        let nonblocking = SocketFabric::new(topo).expect("construct socket fabric");
        let (mut lb, mut ln) = (TrafficLedger::new(), TrafficLedger::new());
        let gb = blocking.all_gather(&shards, &mut lb);
        let mut gn = Vec::new();
        nonblocking
            .start_all_gather(&shards, &mut gn, &mut ln)
            .wait()
            .expect("healthy ring");
        assert_eq!(gn, gb, "start/wait all_gather diverged from blocking");
        let rb = blocking.reduce_scatter(&inputs, &codec, &mut Pcg64::seeded(63), &mut lb);
        let mut rn: Vec<Vec<f32>> = Vec::new();
        nonblocking
            .start_reduce_scatter(&inputs, &codec, &mut Pcg64::seeded(63), &mut rn, &mut ln)
            .wait()
            .expect("healthy ring");
        assert_eq!(rn, rb, "start/wait reduce_scatter diverged from blocking");
        assert_eq!(ln, lb, "ledgers diverged across submission modes");
    }

    #[test]
    fn socket_configured_port_collision_reports_already_bound() {
        if skip_no_loopback() {
            return;
        }
        // Occupy a port, then ask the fabric to pin its rank-0 listener
        // to it: construction must fail naming the real cause (the
        // port is taken), not time out connecting to a peer.
        let squatter = TcpListener::bind((Ipv4Addr::LOCALHOST, 0)).unwrap();
        let port = squatter.local_addr().unwrap().port();
        let err = SocketFabric::with_options(
            Topology::new(2, 1),
            IpAddr::V4(Ipv4Addr::LOCALHOST),
            port,
            DEFAULT_CHECK_EVERY,
            STALL_LIMIT,
        )
        .expect_err("binding an occupied configured port must fail");
        let msg = format!("{err:#}");
        assert!(msg.contains("already bound"), "must name the collision: {msg}");
        assert!(msg.contains(&port.to_string()), "must name the port: {msg}");
        drop(squatter);
    }
}
