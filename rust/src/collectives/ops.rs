//! AllGather and ReduceScatter over encoded payloads.

use super::ledger::TrafficLedger;
use crate::quant::EncodedTensor;
use crate::sim::Topology;

/// Hierarchical AllGather.
///
/// Each rank contributes one encoded shard; the return value is the
/// concatenation of all dequantized shards (identical on every rank,
/// since every rank decodes the same messages — this is what lets the
/// lockstep simulation return a single vector).
///
/// Traffic model (leader-based two-level algorithm):
/// * intra: a shard reaches the node leader and is re-broadcast to the
///   g-1 on-node peers → 2·(g-1)/g-ish, accounted as 2·s·(g-1) per node
///   group in aggregate (gather + broadcast passes);
/// * inter: each node's aggregated shards traverse to the n-1 other
///   leaders once → s·(n-1).
pub fn all_gather(
    topo: &Topology,
    shards: &[EncodedTensor],
    ledger: &mut TrafficLedger,
) -> Vec<f32> {
    assert_eq!(shards.len(), topo.world(), "one shard per rank");
    let g = topo.gpus_per_node;
    let n = topo.nodes;
    let mut out = Vec::new();
    let mut tmp = Vec::new();
    for (rank, enc) in shards.iter().enumerate() {
        let s = enc.byte_size();
        // intra-node: distribute within the source node (gather to
        // leader) and within every destination node (broadcast).
        if g > 1 {
            ledger.record(s * (g - 1), false); // gather to on-node peers
            if n > 1 {
                ledger.record(s * (n - 1) * (g - 1), false); // remote bcasts
            }
        }
        // inter-node: leader forwards once to each other leader.
        if n > 1 {
            ledger.record(s * (n - 1), true);
        }
        let _ = rank;
        enc.decode(&mut tmp);
        out.extend_from_slice(&tmp);
    }
    out
}

/// Hierarchical quantized ReduceScatter.
///
/// `inputs[rank]` is that rank's full-length local contribution (e.g.
/// its microbatch gradient). Output is, per rank, the *sum over all
/// ranks* restricted to the rank's shard.
///
/// Mirrors the paper's hierarchical scheme: contributions are first
/// reduced **in full precision inside each node** (NVLink is cheap),
/// then each node encodes one partial sum per destination shard with
/// `encode` and ships it through the NIC; the destination decodes and
/// sums the n node partials. Quantization error therefore enters once
/// per (node, shard) pair — exactly the inter-node transmission the
/// scheme is designed to compress.
pub fn reduce_scatter<F>(
    topo: &Topology,
    inputs: &[Vec<f32>],
    mut encode: F,
    ledger: &mut TrafficLedger,
) -> Vec<Vec<f32>>
where
    F: FnMut(&[f32]) -> EncodedTensor,
{
    let p = topo.world();
    assert_eq!(inputs.len(), p, "one input per rank");
    let n_elems = inputs[0].len();
    for i in inputs {
        assert_eq!(i.len(), n_elems, "ragged inputs");
    }
    let g = topo.gpus_per_node;

    // Phase 1: intra-node FP32 reduction (accounted on NVLink: each of
    // g-1 non-leader ranks ships its full vector to the node reduce).
    let mut node_partials: Vec<Vec<f32>> = Vec::with_capacity(topo.nodes);
    for node in 0..topo.nodes {
        let mut acc = vec![0.0f32; n_elems];
        for r in topo.ranks_on_node(node) {
            for (a, &x) in acc.iter_mut().zip(&inputs[r]) {
                *a += x;
            }
        }
        if g > 1 {
            ledger.record(n_elems * 4 * (g - 1), false);
        }
        node_partials.push(acc);
    }

    // Phase 2: per destination shard, each node encodes its partial and
    // sends it to the owner's node; owner decodes and sums.
    let mut outputs: Vec<Vec<f32>> = Vec::with_capacity(p);
    let mut tmp = Vec::new();
    for rank in 0..p {
        let range = topo.shard_range(n_elems, rank);
        let dst_node = topo.node_of(rank);
        let mut shard = vec![0.0f32; range.len()];
        for (node, partial) in node_partials.iter().enumerate() {
            let seg = &partial[range.clone()];
            let enc = encode(seg);
            let s = enc.byte_size();
            if node != dst_node {
                ledger.record(s, true);
            } else if g > 1 {
                ledger.record(s, false);
            }
            enc.decode(&mut tmp);
            for (a, &x) in shard.iter_mut().zip(&tmp) {
                *a += x;
            }
        }
        outputs.push(shard);
    }
    outputs
}

/// Flat (non-hierarchical) quantized ReduceScatter — the ablation
/// baseline for the paper's hierarchical scheme. Every rank encodes its
/// own segment for every destination: quantization noise enters once
/// per (rank, shard) pair instead of per (node, shard), and *all*
/// cross-rank messages that leave the node hit the NIC.
pub fn reduce_scatter_flat<F>(
    topo: &Topology,
    inputs: &[Vec<f32>],
    mut encode: F,
    ledger: &mut TrafficLedger,
) -> Vec<Vec<f32>>
where
    F: FnMut(&[f32]) -> EncodedTensor,
{
    let p = topo.world();
    assert_eq!(inputs.len(), p, "one input per rank");
    let n_elems = inputs[0].len();
    let mut outputs = Vec::with_capacity(p);
    let mut tmp = Vec::new();
    for rank in 0..p {
        let range = topo.shard_range(n_elems, rank);
        let dst_node = topo.node_of(rank);
        let mut shard = vec![0.0f32; range.len()];
        for (src, input) in inputs.iter().enumerate() {
            let enc = encode(&input[range.clone()]);
            if src != rank {
                ledger.record(enc.byte_size(), topo.node_of(src) != dst_node);
            }
            enc.decode(&mut tmp);
            for (a, &x) in shard.iter_mut().zip(&tmp) {
                *a += x;
            }
        }
        outputs.push(shard);
    }
    outputs
}

/// AllReduce = ReduceScatter + AllGather of the reduced shards (the
/// classic data-parallel gradient exchange, for DP-vs-FSDP comparisons).
/// Returns the full reduced vector (identical on every rank).
pub fn all_reduce<F, G>(
    topo: &Topology,
    inputs: &[Vec<f32>],
    encode_rs: F,
    mut encode_ag: G,
    ledger: &mut TrafficLedger,
) -> Vec<f32>
where
    F: FnMut(&[f32]) -> EncodedTensor,
    G: FnMut(&[f32]) -> EncodedTensor,
{
    let shards = reduce_scatter(topo, inputs, encode_rs, ledger);
    let encoded: Vec<EncodedTensor> = shards.iter().map(|s| encode_ag(s)).collect();
    all_gather(topo, &encoded, ledger)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::codec::encode_minmax;
    use crate::util::{stats::rel_l2_err, Pcg64};

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::seeded(seed);
        let mut v = vec![0.0; n];
        rng.fill_normal(&mut v, 1.0);
        v
    }

    #[test]
    fn all_gather_fp32_exact() {
        let topo = Topology::new(2, 2);
        let full = rand_vec(103, 1);
        let shards: Vec<EncodedTensor> = (0..4)
            .map(|r| EncodedTensor::fp32(&full[topo.shard_range(103, r)]))
            .collect();
        let mut ledger = TrafficLedger::new();
        let got = all_gather(&topo, &shards, &mut ledger);
        assert_eq!(got, full);
        assert!(ledger.inter_bytes > 0 && ledger.intra_bytes > 0);
    }

    #[test]
    fn all_gather_quantized_close() {
        let topo = Topology::new(2, 4);
        let full = rand_vec(8192, 2);
        let mut rng = Pcg64::seeded(3);
        let shards: Vec<EncodedTensor> = (0..8)
            .map(|r| encode_minmax(&full[topo.shard_range(8192, r)], 8, 1024, false, &mut rng))
            .collect();
        let mut ledger = TrafficLedger::new();
        let got = all_gather(&topo, &shards, &mut ledger);
        assert_eq!(got.len(), full.len());
        assert!(rel_l2_err(&got, &full) < 0.02);
        // 8-bit payload → inter traffic ~4x below fp32
        let fp_shards: Vec<EncodedTensor> = (0..8)
            .map(|r| EncodedTensor::fp32(&full[topo.shard_range(8192, r)]))
            .collect();
        let mut fp_ledger = TrafficLedger::new();
        all_gather(&topo, &fp_shards, &mut fp_ledger);
        let ratio = fp_ledger.inter_bytes as f64 / ledger.inter_bytes as f64;
        assert!((3.0..4.2).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn reduce_scatter_fp32_exact_sum() {
        let topo = Topology::new(2, 2);
        let inputs: Vec<Vec<f32>> = (0..4).map(|r| rand_vec(50, 10 + r as u64)).collect();
        let mut expect = vec![0.0f32; 50];
        for i in &inputs {
            for (a, &x) in expect.iter_mut().zip(i) {
                *a += x;
            }
        }
        let mut ledger = TrafficLedger::new();
        let outs = reduce_scatter(&topo, &inputs, |seg| EncodedTensor::fp32(seg), &mut ledger);
        for (r, shard) in outs.iter().enumerate() {
            let range = topo.shard_range(50, r);
            for (a, &b) in shard.iter().zip(&expect[range]) {
                assert!((a - b).abs() < 1e-4, "rank {r}");
            }
        }
    }

    #[test]
    fn reduce_scatter_quantized_unbiased_and_close() {
        let topo = Topology::new(4, 1);
        let n = 4096;
        let inputs: Vec<Vec<f32>> = (0..4).map(|r| rand_vec(n, 20 + r as u64)).collect();
        let mut expect = vec![0.0f32; n];
        for i in &inputs {
            for (a, &x) in expect.iter_mut().zip(i) {
                *a += x;
            }
        }
        let mut rng = Pcg64::seeded(30);
        let mut ledger = TrafficLedger::new();
        let outs = reduce_scatter(
            &topo,
            &inputs,
            |seg| encode_minmax(seg, 8, 1024, true, &mut rng),
            &mut ledger,
        );
        let got: Vec<f32> = outs.concat();
        assert!(rel_l2_err(&got, &expect) < 0.03);
        assert!(ledger.inter_bytes > 0);
    }

    #[test]
    fn single_node_no_inter_traffic() {
        let topo = Topology::new(1, 4);
        let inputs: Vec<Vec<f32>> = (0..4).map(|r| rand_vec(64, r as u64)).collect();
        let mut ledger = TrafficLedger::new();
        reduce_scatter(&topo, &inputs, |seg| EncodedTensor::fp32(seg), &mut ledger);
        assert_eq!(ledger.inter_bytes, 0);
        assert!(ledger.intra_bytes > 0);
    }

    #[test]
    fn all_reduce_fp32_equals_sum() {
        let topo = Topology::new(2, 2);
        let n = 77;
        let inputs: Vec<Vec<f32>> = (0..4).map(|r| rand_vec(n, 40 + r as u64)).collect();
        let mut expect = vec![0.0f32; n];
        for i in &inputs {
            for (a, &x) in expect.iter_mut().zip(i) {
                *a += x;
            }
        }
        let mut ledger = TrafficLedger::new();
        let got = all_reduce(
            &topo,
            &inputs,
            |s| EncodedTensor::fp32(s),
            |s| EncodedTensor::fp32(s),
            &mut ledger,
        );
        for (a, &b) in got.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-4);
        }
        assert!(ledger.messages > 0);
    }

    #[test]
    fn hierarchical_beats_flat_on_traffic_and_noise() {
        // The paper's §5.1 hierarchical claim, measured: same inputs,
        // same quantizer — hierarchical RS sends fewer inter-node bytes
        // AND accumulates less quantization error (one encode per node
        // vs per rank).
        let topo = Topology::new(4, 4);
        let n = 8192;
        let inputs: Vec<Vec<f32>> =
            (0..topo.world()).map(|r| rand_vec(n, 50 + r as u64)).collect();
        let mut expect = vec![0.0f32; n];
        for i in &inputs {
            for (a, &x) in expect.iter_mut().zip(i) {
                *a += x;
            }
        }
        let mut rng_h = Pcg64::seeded(60);
        let mut ledger_h = TrafficLedger::new();
        let hier = reduce_scatter(
            &topo,
            &inputs,
            |s| encode_minmax(s, 4, 1024, true, &mut rng_h),
            &mut ledger_h,
        );
        let mut rng_f = Pcg64::seeded(60);
        let mut ledger_f = TrafficLedger::new();
        let flat = reduce_scatter_flat(
            &topo,
            &inputs,
            |s| encode_minmax(s, 4, 1024, true, &mut rng_f),
            &mut ledger_f,
        );
        assert!(
            ledger_h.inter_bytes < ledger_f.inter_bytes,
            "hier {} !< flat {}",
            ledger_h.inter_bytes,
            ledger_f.inter_bytes
        );
        // Noise: hierarchical quantizes n node-sums (larger magnitude,
        // fewer terms), flat quantizes P rank contributions — the two
        // variances cancel to first order (k·(√k σ/k)² invariance), so
        // accuracy must be comparable, NOT worse. Traffic is the win.
        let err_h = rel_l2_err(&hier.concat(), &expect);
        let err_f = rel_l2_err(&flat.concat(), &expect);
        assert!(
            err_h < err_f * 1.25,
            "hier err {err_h} much worse than flat {err_f}"
        );
    }

    #[test]
    fn flat_reduce_scatter_fp32_exact() {
        let topo = Topology::new(2, 2);
        let n = 61;
        let inputs: Vec<Vec<f32>> = (0..4).map(|r| rand_vec(n, 70 + r as u64)).collect();
        let mut expect = vec![0.0f32; n];
        for i in &inputs {
            for (a, &x) in expect.iter_mut().zip(i) {
                *a += x;
            }
        }
        let mut ledger = TrafficLedger::new();
        let outs =
            reduce_scatter_flat(&topo, &inputs, |s| EncodedTensor::fp32(s), &mut ledger);
        let got = outs.concat();
        for (a, &b) in got.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn shard_sizes_match_topology() {
        let topo = Topology::new(2, 3);
        let inputs: Vec<Vec<f32>> = (0..6).map(|r| rand_vec(100, r as u64)).collect();
        let mut ledger = TrafficLedger::new();
        let outs = reduce_scatter(&topo, &inputs, |seg| EncodedTensor::fp32(seg), &mut ledger);
        for (r, o) in outs.iter().enumerate() {
            assert_eq!(o.len(), topo.shard_range(100, r).len());
        }
    }
}
