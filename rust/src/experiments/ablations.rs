//! Ablations of QSDP's design choices (DESIGN.md §5 calls these out):
//!
//! A1 — bucket size: accuracy vs meta overhead (paper §5.1 picks 1024;
//!      "naive quantization without bucketing loses > 2 ppl").
//! A2 — hierarchical vs flat collectives: inter-node traffic and
//!      accumulated quantization error at equal bit-width.
//! A3 — stochastic vs deterministic gradient rounding (§5.1 observes
//!      the impact of stochasticity is minimal with bucketing).
//! A4 — dense vs sparse gradient coding (Corollary 3 / §D.3): bytes per
//!      step as the grid coarsens.
//! A5 — comm/compute overlap: per-layer-group `max(compute, comm)`
//!      pipeline clock vs the sequential sum, FSDP vs QSDP across the
//!      paper's model sizes and bandwidths.

use super::traindrv::{base_cfg, run_job};
use crate::collectives::{AsyncFabric, Collective, FlatFabric, LockstepFabric, TrafficLedger};
use crate::quant::qsgd::encode_sparse;
use crate::quant::{Codec, MinMaxCodec, QuantPolicy};
use crate::sim::{StepTimeModel, Topology};
use crate::util::{args::Args, stats::rel_l2_err, table, Pcg64};
use anyhow::Result;

pub fn ablations(args: &Args) -> Result<()> {
    ablation_bucket_size(args)?;
    ablation_hierarchical(args)?;
    ablation_stochastic(args)?;
    ablation_sparse_coding(args)?;
    ablation_overlap(args)?;
    Ok(())
}

/// A1: train with different bucket sizes at 4-bit weights.
fn ablation_bucket_size(args: &Args) -> Result<()> {
    let steps = args.u64_or("steps", 100);
    let mut rows = Vec::new();
    for bucket in [256usize, 1024, 8192, usize::MAX] {
        let mut cfg = base_cfg("nano", steps);
        cfg.policy = QuantPolicy::wg(4, 8);
        cfg.policy.bucket = bucket;
        let log = run_job(&cfg, 0)?;
        let label = if bucket == usize::MAX {
            "global (no bucketing)".to_string()
        } else {
            bucket.to_string()
        };
        rows.push(vec![
            label,
            format!("{:.3}", log.eval_ppl().unwrap_or(f64::NAN)),
            format!("{:.2}", log.total_inter_bytes() as f64 / (1 << 20) as f64),
        ]);
    }
    let headers = ["bucket", "eval_ppl", "inter_MiB"];
    println!(
        "Ablation A1 — bucket size at w4. Note: QSDP always scales per tensor, so \
         even 'global' here is per-tensor min-max — benign for init-scale GPT weights. \
         The paper's >2-ppl 'no bucketing' failure comes from scaling across *grouped* \
         tensors (FSDP flat groups), isolated in fsdp::groups::grouped_global_quantization_is_worse:\n{}",
        table::render(&headers, &rows)
    );
    table::write_csv("results/ablation_bucket.csv", &headers, &rows)?;
    Ok(())
}

/// A2: hierarchical vs flat vs threaded-ring ReduceScatter on a 4x4
/// cluster. Inter-node bytes order as hier < ring < flat (on n nodes x
/// g GPUs the hierarchical scheme crosses the NIC P·(n-1) times per
/// shard-sized message, the ring ~P·n - n, flat P·(P-g)), while the
/// ring re-encodes partials at every hop and so accumulates the most
/// quantization noise — the table makes all three trade-offs visible.
fn ablation_hierarchical(_args: &Args) -> Result<()> {
    let topo = Topology::new(4, 4);
    let n = 1 << 16;
    let mut rng = Pcg64::seeded(11);
    let inputs: Vec<Vec<f32>> = (0..topo.world())
        .map(|_| {
            let mut v = vec![0.0f32; n];
            rng.fill_normal(&mut v, 1.0);
            v
        })
        .collect();
    let mut expect = vec![0.0f32; n];
    for i in &inputs {
        for (a, &x) in expect.iter_mut().zip(i) {
            *a += x;
        }
    }
    // One fabric per backend for the whole sweep: the async backend's
    // persistent rank workers spawn here, once, and serve every row.
    let (hier_fab, flat_fab, ring_fab) =
        (LockstepFabric::new(topo), FlatFabric::new(topo), AsyncFabric::new(topo));
    let mut rows = Vec::new();
    for bits in [4u8, 8] {
        let codec = MinMaxCodec::new(bits, 1024, true);
        let mut rng_h = Pcg64::seeded(21);
        let mut lh = TrafficLedger::new();
        let h = hier_fab.reduce_scatter(&inputs, &codec, &mut rng_h, &mut lh);
        let mut rng_f = Pcg64::seeded(21);
        let mut lf = TrafficLedger::new();
        let f = flat_fab.reduce_scatter(&inputs, &codec, &mut rng_f, &mut lf);
        let mut rng_a = Pcg64::seeded(21);
        let mut la = TrafficLedger::new();
        let a = ring_fab.reduce_scatter(&inputs, &codec, &mut rng_a, &mut la);
        rows.push(vec![
            format!("{bits}"),
            format!("{:.2}", lh.inter_bytes as f64 / (1 << 20) as f64),
            format!("{:.2}", lf.inter_bytes as f64 / (1 << 20) as f64),
            format!("{:.2}", la.inter_bytes as f64 / (1 << 20) as f64),
            format!("{:.5}", rel_l2_err(&h.concat(), &expect)),
            format!("{:.5}", rel_l2_err(&f.concat(), &expect)),
            format!("{:.5}", rel_l2_err(&a.concat(), &expect)),
        ]);
    }
    let headers = [
        "bits", "hier_MiB", "flat_MiB", "ring_MiB", "hier_err", "flat_err", "ring_err",
    ];
    println!(
        "Ablation A2 — hierarchical vs flat vs threaded-ring ReduceScatter, 4x4 ranks (paper §5.1 uses hierarchical to cut inter-node transmissions; the ring re-encodes per hop):\n{}",
        table::render(&headers, &rows)
    );
    table::write_csv("results/ablation_hier.csv", &headers, &rows)?;
    Ok(())
}

/// A3: stochastic vs deterministic gradient rounding at 4 bits.
fn ablation_stochastic(args: &Args) -> Result<()> {
    let steps = args.u64_or("steps", 100);
    let mut rows = Vec::new();
    for spec in ["w8g4", "w8g4+det"] {
        let mut cfg = base_cfg("nano", steps);
        cfg.policy = crate::config::parse_policy(spec)?;
        let log = run_job(&cfg, 0)?;
        rows.push(vec![
            spec.to_string(),
            format!("{:.3}", log.eval_ppl().unwrap_or(f64::NAN)),
        ]);
    }
    let headers = ["policy", "eval_ppl"];
    println!(
        "Ablation A3 — stochastic vs round-to-nearest gradients (paper: with bucketing, stochasticity's impact is minimal):\n{}",
        table::render(&headers, &rows)
    );
    table::write_csv("results/ablation_stoch.csv", &headers, &rows)?;
    Ok(())
}

/// A4: dense packed codec vs sparse Elias-coded QSGD as δ∇ coarsens.
fn ablation_sparse_coding(_args: &Args) -> Result<()> {
    let n = 1 << 18;
    let mut rng = Pcg64::seeded(31);
    let mut g = vec![0.0f32; n];
    rng.fill_normal(&mut g, 0.02); // gradient-like magnitudes
    let dense_bytes = |bits: u8| {
        let e = MinMaxCodec::new(bits, 1024, true).encode(&g, &mut Pcg64::seeded(32));
        e.byte_size()
    };
    let mut rows = Vec::new();
    let linf = g.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
    for (label, delta) in [
        ("fine (δ=max/255)", linf / 255.0),
        ("mid  (δ=max/15)", linf / 15.0),
        ("coarse (δ=max)", linf),
    ] {
        let e = encode_sparse(&g, delta, &mut rng);
        let d = e.decode();
        rows.push(vec![
            label.to_string(),
            format!("{}", e.nnz),
            format!("{:.1}", e.byte_size() as f64 / 1024.0),
            format!("{:.4}", rel_l2_err(&d, &g)),
        ]);
    }
    rows.push(vec![
        "dense 8-bit packed".into(),
        format!("{n}"),
        format!("{:.1}", dense_bytes(8) as f64 / 1024.0),
        "-".into(),
    ]);
    rows.push(vec![
        "dense 4-bit packed".into(),
        format!("{n}"),
        format!("{:.1}", dense_bytes(4) as f64 / 1024.0),
        "-".into(),
    ]);
    let headers = ["coding", "nnz", "KiB", "rel_err"];
    println!(
        "Ablation A4 — dense vs sparse gradient coding, {n} values (Corollary 3: coarser grid -> fewer bits, more variance):\n{}",
        table::render(&headers, &rows)
    );
    table::write_csv("results/ablation_sparse.csv", &headers, &rows)?;
    Ok(())
}

/// A5: comm/compute overlap. For each paper model and bandwidth, time
/// one optimizer step sequentially (compute + comm) and under the
/// per-layer-group pipeline (sum of `max(compute, comm)` per group,
/// [`StepTimeModel::step_overlapped`]); report how much communication
/// the pipeline hides. The overlapped clock is strictly below the
/// sequential sum whenever any group has both compute and comm to
/// trade, and the hidden time can never exceed the compute budget —
/// both invariants are pinned in `sim::steptime`'s `overlap_` tests.
fn ablation_overlap(_args: &Args) -> Result<()> {
    let mut rows = Vec::new();
    for (label, policy) in [
        ("FSDP", QuantPolicy::baseline()),
        ("QSDP", QuantPolicy::qsdp_default()),
    ] {
        for m in ["gpt125m", "gpt350m", "gpt1.3b"] {
            for bw in [10.0, 50.0, 100.0] {
                let model = StepTimeModel::paper(m, bw).unwrap();
                let o = model.step_overlapped(&policy);
                rows.push(vec![
                    label.to_string(),
                    m.to_string(),
                    format!("{bw:.0}"),
                    format!("{:.2}", o.sequential()),
                    format!("{:.2}", o.overlapped_s),
                    format!("{:.2}", o.hidden()),
                    format!("{:.2}", model.measured_overlap(&policy)),
                ]);
            }
        }
    }
    let headers = [
        "system", "model", "Gbps", "sequential_s", "overlapped_s", "hidden_s", "overlap_frac",
    ];
    println!(
        "Ablation A5 — comm/compute overlap: per-layer-group max(compute, comm) vs the sequential sum (overlap_frac = hidden comm / total comm):\n{}",
        table::render(&headers, &rows)
    );
    table::write_csv("results/ablation_overlap.csv", &headers, &rows)?;
    Ok(())
}
