//! Experiment drivers: one entry point per paper table/figure
//! (DESIGN.md §5 maps each to its modules). Every driver prints a
//! paper-shaped table and writes a CSV under `results/`.

pub mod ablations;
pub mod figures;
pub mod tables;
pub mod traindrv;

pub use ablations::ablations;
pub use figures::{figure3, figure4, figure6, figure7};
pub use tables::{table1, table2, table3, table5, table6};

use crate::model::spec::{artifacts_root, GptDims, Manifest};
use crate::util::args::Args;
use anyhow::Result;

/// `qsdp train` — run one training job and summarize.
pub fn cmd_train(args: &Args) -> Result<()> {
    // Standalone elastic rank mode: `qsdp launch` workers (or a
    // hand-started rank) carry `--rank`/`QSDP_RANK` and run the
    // fault-tolerant driver instead of the one-process job.
    if let Some(ctx) = crate::runtime::elastic::WorkerContext::detect(args)? {
        return crate::runtime::elastic::run_train_worker(&ctx, args);
    }
    let cfg = crate::config::RunConfig::from_args(args)?;
    let log = traindrv::run_job(&cfg, args.u64_or("log-every", 10))?;
    let name = crate::config::policy_name(&cfg.policy);
    println!(
        "model={} policy={} steps={} final_loss={:.4} final_ppl={:.2} eval_ppl={:?} sim_time={:.1}s inter={:.1}MiB",
        cfg.model,
        name,
        cfg.steps,
        log.final_loss(10),
        log.final_ppl(10),
        log.eval_ppl(),
        log.total_sim_s(),
        log.total_inter_bytes() as f64 / (1 << 20) as f64
    );
    let path = format!("results/train_{}_{}.csv", cfg.model, name);
    log.write_csv(&path)?;
    println!("wrote {path}");
    Ok(())
}

/// `qsdp theory` — Theorem 2 / Corollary 3 convergence validation.
pub fn cmd_theory(args: &Args) -> Result<()> {
    use crate::theory::{theorem2_delta, PlQuadratic, QsgdIteration};
    use crate::util::{table, Pcg64};
    let dim = args.usize_or("dim", 64);
    let steps = args.usize_or("steps", 500);
    let mut rows = Vec::new();
    for &kappa in &[2.0f32, 4.0, 8.0] {
        let (alpha, beta) = (1.0f32, kappa);
        let f = PlQuadratic::new(dim, alpha, beta, 42);
        let delta_star = 0.05f32;
        let mut rng = Pcg64::seeded(1);
        let bench = f.expected_best_on_lattice(delta_star, &mut rng, 500);
        for &(label, delta) in &[
            ("thm2", theorem2_delta(1.0, alpha, beta, delta_star)),
            ("coarse(d*)", delta_star),
        ] {
            let it = QsgdIteration { eta: 1.0, delta, grad_quant: None, sigma: 0.0 };
            let tr = it.run(&f, &vec![0.0; dim], steps, &mut rng);
            let f_t = *tr.f_vals.last().unwrap();
            // first step reaching within 1e-3 of the benchmark
            let hit = tr
                .f_vals
                .iter()
                .position(|&v| v <= bench + 1e-3)
                .map(|i| i.to_string())
                .unwrap_or_else(|| "-".into());
            rows.push(vec![
                format!("{kappa}"),
                label.to_string(),
                format!("{delta:.2e}"),
                format!("{:.3e}", f_t),
                format!("{bench:.3e}"),
                hit,
            ]);
        }
    }
    let headers = ["beta/alpha", "grid", "delta", "f(x_T)", "E f(x*)", "steps to eps"];
    let t = table::render(&headers, &rows);
    println!("Theorem 2 validation (quadratic PL testbed, dim {dim}):\n{t}");
    table::write_csv("results/theory.csv", &headers, &rows)?;
    Ok(())
}

/// `qsdp info` — inventory of artifacts and model configs.
pub fn info(_args: &Args) -> Result<()> {
    let root = artifacts_root();
    println!("artifacts root: {}", root.display());
    let mut names: Vec<String> = std::fs::read_dir(&root)?
        .filter_map(|e| e.ok())
        .filter(|e| e.path().join("manifest.txt").exists())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .collect();
    names.sort();
    for name in names {
        let m = Manifest::load(&root, &name)?;
        println!(
            "  {:8} d={} L={} heads={} vocab={} seq={} B={} params={} artifacts={}",
            m.dims.name,
            m.dims.d_model,
            m.dims.n_layer,
            m.dims.n_head,
            m.dims.vocab,
            m.dims.seq_len,
            m.dims.batch_size,
            m.n_params,
            m.artifacts.len()
        );
    }
    println!("paper-size analytic configs:");
    for name in ["gpt125m", "gpt350m", "gpt1.3b"] {
        let d = GptDims::paper(name).unwrap();
        println!(
            "  {:8} d={} L={} params={:.0}M step_flops={:.2e}",
            name,
            d.d_model,
            d.n_layer,
            d.n_params() as f64 / 1e6,
            d.step_flops()
        );
    }
    Ok(())
}

/// `qsdp reproduce` — regenerate everything (quick mode by default;
/// pass --steps to deepen the accuracy-tier runs).
pub fn reproduce(args: &Args) -> Result<()> {
    println!("=== Table 5 (step-time grid, analytic) ===");
    table5(args)?;
    println!("=== Figure 4 (step time vs bandwidth) ===");
    figure4(args)?;
    println!("=== Figure 6 (fake compression sweep) ===");
    figure6(args)?;
    println!("=== Theorem 2 ===");
    cmd_theory(args)?;
    println!("=== Table 1 (perplexity recovery) ===");
    table1(args)?;
    println!("=== Table 2 (W/G bit grid) ===");
    table2(args)?;
    println!("=== Table 3 (learned quantization) ===");
    table3(args)?;
    println!("=== Table 6 (extreme low bits) ===");
    table6(args)?;
    println!("=== Figure 3 (ppl vs time) ===");
    figure3(args)?;
    println!("=== Figure 7/8 (compression error traces) ===");
    figure7(args)?;
    println!("done; CSVs under results/");
    Ok(())
}
