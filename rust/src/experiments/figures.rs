//! Paper-figure regenerators (Figures 3, 4, 6, 7/8).

use super::traindrv::{base_cfg, run_job};
use crate::collectives::TwoLevelCodecs;
use crate::config::parse_policy;
use crate::quant::{learned::normalize_bucketwise, LearnedLevels, MinMaxQuantizer, QuantPolicy};
use crate::sim::StepTimeModel;
use crate::util::{args::Args, stats::rel_l2_err, table, Pcg64};
use anyhow::Result;

/// Figure 3 — perplexity vs wall time, FSDP vs QSDP at 10 Gbps.
///
/// Two-tier composition (DESIGN.md §2): the *accuracy trajectory* comes
/// from real training of the scaled model with real quantized
/// collectives; the *clock* charges each optimizer step with the
/// paper-size (1.3B @ 10 Gbps) step time of the corresponding policy —
/// the quantity the paper's x-axis measures. The scaled-model
/// collectives also tick a secondary clock from their actual encoded
/// bytes (column `sim_scaled_s`) as a sanity check.
pub fn figure3(args: &Args) -> Result<()> {
    let steps = args.u64_or("steps", 150);
    let model = args.str_or("config", "nano");
    let mut rows = Vec::new();
    let mut finish = Vec::new();
    for policy in ["baseline", "w8g8"] {
        let mut cfg = base_cfg(&model, steps);
        cfg.policy = parse_policy(policy)?;
        cfg.inter_gbps = 10.0;
        cfg.eval_every = (steps / 8).max(1);
        // paper-scale per-step cost for this policy
        let paper_step = StepTimeModel::paper("gpt1.3b", 10.0)
            .unwrap()
            .step_total(&cfg.policy);
        let log = run_job(&cfg, 0)?;
        let mut cum = 0.0;
        let mut cum_at = std::collections::HashMap::new();
        for r in &log.steps {
            cum += r.sim_s;
            cum_at.insert(r.step, cum);
        }
        for (step, loss) in &log.evals {
            rows.push(vec![
                policy.to_string(),
                step.to_string(),
                format!("{:.1}", *step as f64 * paper_step),
                format!("{:.2}", cum_at.get(step).copied().unwrap_or(cum)),
                format!("{:.3}", loss.exp()),
            ]);
        }
        finish.push((policy, steps as f64 * paper_step));
    }
    let headers = ["policy", "step", "time_1.3B@10G_s", "sim_scaled_s", "eval_ppl"];
    let t = table::render(&headers, &rows);
    println!(
        "Figure 3 — ppl vs wall time at 10 Gbps, accuracy from {model} training, clock from the 1.3B step model:\n{t}"
    );
    if let [(_, tb), (_, tq)] = finish[..] {
        println!(
            "time-to-final-ppl: FSDP {tb:.0}s vs QSDP {tq:.0}s -> speedup {:.2}x (paper: 2.2x)",
            tb / tq
        );
    }
    table::write_csv("results/figure3.csv", &headers, &rows)?;
    Ok(())
}

/// Figure 4 — step time vs inter-node bandwidth for the paper's three
/// model sizes, FSDP vs QSDP (analytic, real codec byte counts). The
/// `+ovl` rows replace the fixed paper overlap constant with the
/// fraction the per-layer-group pipeline actually achieves
/// ([`StepTimeModel::measured_overlap`] threaded through
/// `total_with_overlap`). The `QSDP+hier` rows time the hierarchical
/// recipe ([`StepTimeModel::step_hier`]): hpZ intra-node re-gathers
/// plus the two-level 8-bit/4-bit gradient reduce-scatter.
pub fn figure4(args: &Args) -> Result<()> {
    let bws = [10.0, 50.0, 100.0];
    let models = ["gpt125m", "gpt350m", "gpt1.3b"];
    let fsdp = QuantPolicy::baseline();
    let qsdp = QuantPolicy::qsdp_default();
    let codecs = TwoLevelCodecs::default();
    let mut rows = Vec::new();
    for m in models {
        let systems = [
            ("FSDP", &fsdp, false),
            ("FSDP+ovl", &fsdp, true),
            ("QSDP", &qsdp, false),
            ("QSDP+ovl", &qsdp, true),
        ];
        for (label, p, measured) in systems {
            let mut row = vec![m.to_string(), label.to_string()];
            for bw in bws {
                let model = StepTimeModel::paper(m, bw).unwrap();
                let t = if measured {
                    model.step(p).total_with_overlap(model.measured_overlap(p))
                } else {
                    model.step_total(p)
                };
                row.push(format!("{t:.2}"));
            }
            rows.push(row);
        }
        let mut hier = vec![m.to_string(), "QSDP+hier".to_string()];
        for bw in bws {
            let model = StepTimeModel::paper(m, bw).unwrap();
            let t = model
                .step_hier(&qsdp, &codecs)
                .total_with_overlap(model.overlap);
            hier.push(format!("{t:.2}"));
        }
        rows.push(hier);
    }
    let _ = args;
    let headers = ["model", "system", "10Gbps", "50Gbps", "100Gbps"];
    let t = table::render(&headers, &rows);
    println!(
        "Figure 4 — step time (s) vs bandwidth (paper: QSDP ~constant, FSDP 1.3B 2.25x slower at 10 Gbps; +ovl = measured per-layer overlap instead of the fixed 0.6; +hier = hpZ re-gathers + two-level 8/4-bit grad RS):\n{t}"
    );
    table::write_csv("results/figure4.csv", &headers, &rows)?;
    Ok(())
}

/// Figure 6 — fake-compression ratio sweep vs step time per model and
/// bandwidth, with the ideal (no communication) dashed line. Each
/// `+ovl` row re-runs the same ratio sweep under the per-layer-group
/// overlapped clock ([`StepTimeModel::step_overlapped_fake`]).
pub fn figure6(args: &Args) -> Result<()> {
    let bws = [10.0, 50.0, 100.0];
    let models = ["gpt125m", "gpt350m", "gpt1.3b"];
    let ratios = [1.0, 2.0, 4.0, 8.0];
    let mut rows = Vec::new();
    for m in models {
        for bw in bws {
            let model = StepTimeModel::paper(m, bw).unwrap();
            let mut row = vec![m.to_string(), format!("{bw:.0}")];
            for r in ratios {
                row.push(format!("{:.2}", model.fake_total(r, r)));
            }
            row.push(format!("{:.2}", model.fake_total(1e12, 1e12)));
            rows.push(row);
            let mut ovl = vec![format!("{m}+ovl"), format!("{bw:.0}")];
            for r in ratios {
                ovl.push(format!("{:.2}", model.step_overlapped_fake(r, r).overlapped_s));
            }
            ovl.push(format!("{:.2}", model.step_overlapped_fake(1e12, 1e12).overlapped_s));
            rows.push(ovl);
        }
    }
    let _ = args;
    let headers = ["model", "Gbps", "1x", "2x", "4x", "8x", "ideal"];
    let t = table::render(&headers, &rows);
    println!(
        "Figure 6 — step time (s) vs compression ratio (paper: 8x nearly reaches the ideal line for 1.3B; +ovl = per-layer-group overlapped clock):\n{t}"
    );
    table::write_csv("results/figure6.csv", &headers, &rows)?;
    Ok(())
}

/// Figures 7/8 — compression error over training, uniform vs learned
/// levels, for an attention layer and the LM head (W5G4 setting).
pub fn figure7(args: &Args) -> Result<()> {
    let steps = args.u64_or("steps", 120);
    let model = args.str_or("config", "nano");
    let bits = 5u8;
    let snapshots = 6u64;
    let every = (steps / snapshots).max(1);

    // Train a w5g4 model, snapshotting two layers' weights.
    use crate::coordinator::{Trainer, TrainerOptions};
    use crate::model::spec::artifacts_root;
    let mut cfg = base_cfg(&model, steps);
    cfg.policy = QuantPolicy::wg(bits, 4);
    let mut tr = Trainer::new(
        super::traindrv::engine(),
        &artifacts_root(),
        cfg,
        TrainerOptions::default(),
    )?;
    // locate the tensors: first attention qkv + lm head
    let specs: Vec<String> = tr
        .dims()
        .param_spec()
        .iter()
        .map(|s| s.name.clone())
        .collect();
    let attn_idx = specs.iter().position(|n| n == "h0.attn.qkv.w").unwrap();
    let head_idx = specs.iter().position(|n| n == "lm_head").unwrap();

    let mut rows = Vec::new();
    let mut rng = Pcg64::seeded(99);
    let bucket = 1024;
    for s in 0..steps {
        tr.step_once()?;
        if (s + 1) % every == 0 {
            let master = tr.master_params();
            for (label, idx) in [("attn.qkv", attn_idx), ("lm_head", head_idx)] {
                let w = &master[idx];
                // uniform error
                let mut u = w.clone();
                MinMaxQuantizer::new(bits, bucket, false).apply(&mut u, &mut rng);
                let eu = rel_l2_err(&u, w);
                // learned error (fit on this snapshot, as the paper's
                // periodic refresh does)
                let mut ll = LearnedLevels::uniform(bits);
                ll.fit(&normalize_bucketwise(w, bucket), 0.01, 6);
                let mut lq = w.clone();
                ll.apply(&mut lq, bucket);
                let el = rel_l2_err(&lq, w);
                rows.push(vec![
                    label.to_string(),
                    (s + 1).to_string(),
                    format!("{eu:.5}"),
                    format!("{el:.5}"),
                    format!("{:.3}", eu / el.max(1e-12)),
                ]);
            }
        }
    }
    let headers = ["layer", "step", "uniform_err", "learned_err", "ratio"];
    let t = table::render(&headers, &rows);
    println!(
        "Figures 7/8 — relative L2 compression error over training, W{bits} (paper: learned error consistently below uniform):\n{t}"
    );
    table::write_csv("results/figure7.csv", &headers, &rows)?;
    Ok(())
}
