//! Shared training-job driver for the accuracy-tier experiments.
//!
//! All table/figure drivers that run *real training* go through
//! [`run_job`], which shares one PJRT engine (executable cache) across
//! jobs in a process.

use crate::config::RunConfig;
use crate::coordinator::{Trainer, TrainerOptions};
use crate::metrics::TrainLog;
use crate::model::spec::artifacts_root;
use crate::runtime::Engine;
use anyhow::Result;
use std::sync::Arc;

// The xla crate's PJRT handles are Rc-based (not Send/Sync); the whole
// coordinator is single-threaded by design (1 core), so a thread-local
// engine gives the same executable-cache sharing.
thread_local! {
    static ENGINE: std::cell::OnceCell<Arc<Engine>> = const { std::cell::OnceCell::new() };
}

/// The per-thread PJRT engine (shared executable cache).
pub fn engine() -> Arc<Engine> {
    ENGINE.with(|c| {
        c.get_or_init(|| Arc::new(Engine::cpu().expect("PJRT CPU client")))
            .clone()
    })
}

/// Run one training job to completion and return its log.
pub fn run_job(cfg: &RunConfig, log_every: u64) -> Result<TrainLog> {
    let mut tr = Trainer::new(
        engine(),
        &artifacts_root(),
        cfg.clone(),
        TrainerOptions { log_every },
    )?;
    tr.run(cfg.steps)?;
    // final eval for the ppl tables
    let l = tr.eval()?;
    tr.log.push_eval(tr.steps_done(), l as f64);
    Ok(tr.log)
}

/// Default RunConfig for experiment drivers: small cluster, fast model.
pub fn base_cfg(model: &str, steps: u64) -> RunConfig {
    use crate::sim::Topology;
    RunConfig {
        model: model.to_string(),
        policy: crate::quant::QuantPolicy::baseline(),
        variant: crate::runtime::gpt::StepVariant::Plain,
        topo: Topology::new(2, 2),
        steps,
        warmup: (steps / 10).max(1),
        seed: 7,
        lr: 3e-3,
        eval_every: 0,
        learned_at: vec![],
        corpus_len: 200_000,
        inter_gbps: 10.0,
        n_accum: 1,
        overlap: false,
        hier: false,
        hpz: false,
        fabric: crate::config::FabricKind::default(),
        fabric_opts: crate::config::FabricOptions::default(),
    }
}
