//! Paper-table regenerators (Tables 1, 2, 3, 5, 6).

use super::traindrv::{base_cfg, run_job};
use crate::collectives::TwoLevelCodecs;
use crate::config::parse_policy;
use crate::quant::QuantPolicy;
use crate::sim::StepTimeModel;
use crate::util::{args::Args, table};
use anyhow::Result;

/// Table 1 — perplexity recovery: baseline vs QSDP W8G8 across model
/// sizes. Paper: GPT 125M/350M/1.3B on C4; here: the scaled ladder
/// nano/tiny(/small with --full) on the synthetic corpus (DESIGN.md §2).
pub fn table1(args: &Args) -> Result<()> {
    let steps = args.u64_or("steps", 150);
    let mut models = vec!["nano", "tiny"];
    if args.bool_or("full", false) {
        models.push("small");
    }
    let mut rows = Vec::new();
    for policy in ["baseline", "w8g8"] {
        let mut row = vec![policy.to_string()];
        for m in &models {
            let mut cfg = base_cfg(m, steps);
            cfg.policy = parse_policy(policy)?;
            let log = run_job(&cfg, 0)?;
            row.push(format!("{:.2}", log.eval_ppl().unwrap_or(f64::NAN)));
        }
        rows.push(row);
    }
    let mut headers = vec!["policy"];
    headers.extend(models.iter().copied());
    let t = table::render(&headers, &rows);
    println!("Table 1 — final eval perplexity, {} steps (paper: 125M 35.81/35.58, 350M 23.94/23.95, 1.3B 18.00/18.34):\n{t}", steps);
    table::write_csv("results/table1.csv", &headers, &rows)?;
    Ok(())
}

/// Table 2 — final perplexity for every (weight, grad) bit pair in
/// {6,5,4}² (uniform quantization, smallest model).
pub fn table2(args: &Args) -> Result<()> {
    let steps = args.u64_or("steps", 150);
    let model = args.str_or("config", "nano");
    let bits = [6u8, 5, 4];
    let mut rows = Vec::new();
    for w in bits {
        let mut row = vec![format!("w{w}")];
        for g in bits {
            let mut cfg = base_cfg(&model, steps);
            cfg.policy = QuantPolicy::wg(w, g);
            let log = run_job(&cfg, 0)?;
            row.push(format!("{:.2}", log.eval_ppl().unwrap_or(f64::NAN)));
        }
        rows.push(row);
    }
    let headers = ["weights\\grads", "g6", "g5", "g4"];
    let t = table::render(&headers, &rows);
    println!(
        "Table 2 — uniform low-bit grid, {model} @ {steps} steps (paper 125M: w6 35.74/36.08/35.84; w5 36.01/35.94/36.36; w4 37.11/37.38/37.61):\n{t}"
    );
    table::write_csv("results/table2.csv", &headers, &rows)?;
    Ok(())
}

/// Table 3 — uniform vs learned levels at {w6g4, w5g4, w4g4, w4g32},
/// plus the baseline.
pub fn table3(args: &Args) -> Result<()> {
    let steps = args.u64_or("steps", 150);
    let model = args.str_or("config", "nano");
    let specs = ["baseline", "w6g4", "w5g4", "w4g4", "w4g32"];
    let mut rows = Vec::new();
    for mode in ["uniform", "learned"] {
        let mut row = vec![mode.to_string()];
        for spec in specs {
            let mut cfg = base_cfg(&model, steps);
            cfg.policy = parse_policy(spec)?;
            if mode == "learned" && spec != "baseline" {
                // refresh after warmup, paper-style
                cfg.learned_at = vec![(steps / 8).max(1), (steps / 2).max(2)];
            }
            let log = run_job(&cfg, 0)?;
            row.push(format!("{:.2}", log.eval_ppl().unwrap_or(f64::NAN)));
        }
        rows.push(row);
    }
    let headers = ["levels", "baseline", "w6g4", "w5g4", "w4g4", "w4g32"];
    let t = table::render(&headers, &rows);
    println!(
        "Table 3 — learned vs uniform levels, {model} @ {steps} steps (paper 125M uniform: 35.81/35.81/36.34/37.61/37.11; learned: 35.61/35.75/36.01/36.94/36.55):\n{t}"
    );
    table::write_csv("results/table3.csv", &headers, &rows)?;
    Ok(())
}

/// Table 5 — step time (s) for the weight×grad compression-ratio grid,
/// 1.3B @ 100 Gbps (analytic, fake compression as in Appendix B). The
/// base grid charges the paper's fixed overlap constant through
/// [`crate::sim::StepBreakdown::total_with_overlap`]; each `w/N+ovl`
/// row re-times the same grid under the per-layer-group overlapped
/// clock ([`StepTimeModel::step_overlapped_fake`]).
pub fn table5(args: &Args) -> Result<()> {
    let model = args.str_or("model", "gpt1.3b");
    let bw = args.f64_or("bandwidth", 100.0);
    let m = StepTimeModel::paper(&model, bw)
        .ok_or_else(|| anyhow::anyhow!("unknown paper model {model}"))?;
    let ratios = [1.0, 2.0, 4.0, 8.0];
    let mut rows = Vec::new();
    for w in ratios {
        let mut row = vec![format!("w/{w:.0}")];
        for g in ratios {
            row.push(format!("{:.2}", m.fake_total(w, g)));
        }
        rows.push(row);
        let mut ovl = vec![format!("w/{w:.0}+ovl")];
        for g in ratios {
            ovl.push(format!("{:.2}", m.step_overlapped_fake(w, g).overlapped_s));
        }
        rows.push(ovl);
    }
    let headers = ["weights\\grads", "g/1", "g/2", "g/4", "g/8"];
    let t = table::render(&headers, &rows);
    println!(
        "Table 5 — step time (s), {model} @ {bw} Gbps (paper row w/1: 23.23 21.36 20.62 20.2; w/8: 16.62 14.52 13.66 13.21; +ovl = per-layer-group overlapped clock):\n{t}"
    );
    table::write_csv("results/table5.csv", &headers, &rows)?;

    // Hierarchical supplement: flat w8g8 vs the two-level recipe (hpZ
    // intra-node re-gathers, 8-bit intra / 4-bit inter gradient RS).
    // The `inter_MB` column is the per-step cross-node gradient payload
    // — the byte reduction the hierarchical collectives buy.
    let qsdp = QuantPolicy::qsdp_default();
    let codecs = TwoLevelCodecs::default();
    let flat = m.step(&qsdp);
    let hier = m.step_hier(&qsdp, &codecs);
    let flat_gb = m.grad_bytes(&qsdp);
    let (_, hier_gb) = m.hier_grad_bytes(&qsdp, &codecs);
    let mb = |b: usize| format!("{:.1}", b as f64 / 1e6);
    let hrows = vec![
        vec![
            "QSDP w8g8".to_string(),
            format!("{:.2}", flat.total_with_overlap(m.overlap)),
            format!("{:.2}", flat.weight_comm_s),
            format!("{:.2}", flat.grad_comm_s),
            mb(flat_gb),
        ],
        vec![
            "QSDP+hier 8/4".to_string(),
            format!("{:.2}", hier.total_with_overlap(m.overlap)),
            format!("{:.2}", hier.weight_comm_s),
            format!("{:.2}", hier.grad_comm_s),
            mb(hier_gb),
        ],
    ];
    let hheaders = ["system", "total_s", "weight_s", "grad_s", "inter_MB"];
    let ht = table::render(&hheaders, &hrows);
    println!(
        "Table 5 (hier supplement) — {model} @ {bw} Gbps, cross-node grad payload drops {:.2}x under the 4-bit inter hop:\n{ht}",
        flat_gb as f64 / hier_gb.max(1) as f64
    );
    table::write_csv("results/table5_hier.csv", &hheaders, &hrows)?;
    Ok(())
}

/// Table 6 — extreme low-bit configs, uniform vs learned.
pub fn table6(args: &Args) -> Result<()> {
    let steps = args.u64_or("steps", 150);
    let model = args.str_or("config", "nano");
    let specs = ["baseline", "w3g32", "w2g32", "w8g3", "w8g2"];
    let mut rows = Vec::new();
    for mode in ["uniform", "learned"] {
        let mut row = vec![mode.to_string()];
        for spec in specs {
            let mut cfg = base_cfg(&model, steps);
            cfg.policy = parse_policy(spec)?;
            if mode == "learned" && spec != "baseline" {
                cfg.learned_at = vec![(steps / 8).max(1), (steps / 2).max(2)];
            }
            let log = run_job(&cfg, 0)?;
            row.push(format!("{:.2}", log.eval_ppl().unwrap_or(f64::NAN)));
        }
        rows.push(row);
    }
    let headers = ["levels", "baseline", "w3g32", "w2g32", "w8g3", "w8g2"];
    let t = table::render(&headers, &rows);
    println!(
        "Table 6 — extreme low-bit, {model} @ {steps} steps (paper 125M uniform: 35.81/45.53/57.92/39.91/44.79; learned: 35.61/42.31/56.54/37.72/44.65):\n{t}"
    );
    table::write_csv("results/table6.csv", &headers, &rows)?;
    Ok(())
}
