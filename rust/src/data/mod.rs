//! Training data: a synthetic Markov-chain corpus with controllable
//! structure (stand-in for C4 — see DESIGN.md §2) and a deterministic
//! per-rank batch sampler.

pub mod corpus;
pub mod sampler;

pub use corpus::MarkovCorpus;
pub use sampler::Sampler;
