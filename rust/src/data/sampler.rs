//! Deterministic per-rank batch sampler.
//!
//! The corpus is split into `world` contiguous shards (data parallelism:
//! every rank trains on disjoint data); batches are random windows from
//! the rank's shard, seeded per rank so runs are reproducible.

use super::corpus::MarkovCorpus;
use crate::util::Pcg64;
use std::sync::Arc;

pub struct Sampler {
    corpus: Arc<MarkovCorpus>,
    start: usize,
    len: usize,
    rng: Pcg64,
}

impl Sampler {
    /// Sampler for `rank` of `world` with the given seed.
    pub fn new(corpus: Arc<MarkovCorpus>, rank: usize, world: usize, seed: u64) -> Self {
        assert!(rank < world);
        let shard = corpus.tokens.len() / world;
        assert!(shard > 1, "corpus too small for world size");
        Sampler {
            start: rank * shard,
            len: shard,
            corpus,
            rng: Pcg64::new(seed, rank as u64 + 1),
        }
    }

    /// Held-out sampler (last shard slice reserved for eval).
    pub fn eval(corpus: Arc<MarkovCorpus>, seed: u64) -> Self {
        let n = corpus.tokens.len();
        let len = (n / 10).max(2);
        Sampler {
            start: n - len,
            len,
            corpus,
            rng: Pcg64::new(seed, 0xEEE),
        }
    }

    /// Sample a (batch × seq) token matrix, flattened row-major.
    pub fn batch(&mut self, batch: usize, seq: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let max_start = self.len.saturating_sub(seq).max(1);
            let off = self.start + self.rng.below(max_start as u64) as usize;
            for i in 0..seq {
                // wrap within the corpus for tiny shards
                let idx = (off + i) % self.corpus.tokens.len();
                out.push(self.corpus.tokens[idx]);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> Arc<MarkovCorpus> {
        Arc::new(MarkovCorpus::generate(64, 10_000, 1))
    }

    #[test]
    fn batch_shape_and_range() {
        let mut s = Sampler::new(corpus(), 0, 4, 5);
        let b = s.batch(3, 17);
        assert_eq!(b.len(), 3 * 17);
        assert!(b.iter().all(|&t| (0..64).contains(&t)));
    }

    #[test]
    fn ranks_draw_from_disjoint_shards() {
        let c = corpus();
        let mut s0 = Sampler::new(c.clone(), 0, 2, 5);
        let mut s1 = Sampler::new(c.clone(), 1, 2, 5);
        // windows from rank 0 start in [0, 5000), rank 1 in [5000, 10000)
        // verify by reconstructing offsets: sample many and check token
        // subsequences come from the right half.
        let b0 = s0.batch(8, 32);
        let b1 = s1.batch(8, 32);
        let find = |win: &[i32]| {
            c.tokens
                .windows(32)
                .position(|w| w == win)
                .expect("window must exist in corpus")
        };
        for row in b0.chunks(32) {
            assert!(find(row) < 5000);
        }
        for row in b1.chunks(32) {
            assert!(find(row) >= 4969); // window may straddle by < seq
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let c = corpus();
        let a = Sampler::new(c.clone(), 0, 2, 9).batch(2, 8);
        let b = Sampler::new(c.clone(), 0, 2, 9).batch(2, 8);
        assert_eq!(a, b);
        let d = Sampler::new(c, 0, 2, 10).batch(2, 8);
        assert_ne!(a, d);
    }

    #[test]
    fn eval_sampler_uses_tail() {
        let c = corpus();
        let mut e = Sampler::eval(c.clone(), 3);
        let b = e.batch(4, 16);
        let find = |win: &[i32]| c.tokens.windows(16).position(|w| w == win).unwrap();
        for row in b.chunks(16) {
            assert!(find(row) >= 8969);
        }
    }
}
