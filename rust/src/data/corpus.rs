//! Synthetic order-1 Markov corpus.
//!
//! Each token has `SUCCESSORS` fixed pseudorandom successors drawn with
//! a skewed distribution, giving the chain an entropy rate of ~1.2 nats
//! — far below the uniform ln(vocab) — so a language model trained on
//! it shows a clear, monotone loss curve from ln(V) toward the chain
//! entropy. This preserves the property the perplexity-recovery
//! experiments need: quantized and FP32 training can be compared by
//! how well they fit real sequential structure.

use crate::util::Pcg64;

const SUCCESSORS: usize = 4;
const PROBS: [f64; SUCCESSORS] = [0.55, 0.25, 0.15, 0.05];

/// A generated token stream plus its transition structure.
pub struct MarkovCorpus {
    pub vocab: usize,
    pub tokens: Vec<i32>,
    successors: Vec<[i32; SUCCESSORS]>,
}

impl MarkovCorpus {
    /// Build a corpus of `len` tokens over `vocab` symbols.
    pub fn generate(vocab: usize, len: usize, seed: u64) -> Self {
        assert!(vocab >= SUCCESSORS);
        let mut rng = Pcg64::new(seed, 1);
        let successors: Vec<[i32; SUCCESSORS]> = (0..vocab)
            .map(|_| {
                let mut s = [0i32; SUCCESSORS];
                for slot in s.iter_mut() {
                    *slot = rng.below(vocab as u64) as i32;
                }
                s
            })
            .collect();
        let mut tokens = Vec::with_capacity(len);
        let mut cur = rng.below(vocab as u64) as i32;
        for _ in 0..len {
            tokens.push(cur);
            let u = rng.next_f64();
            let mut acc = 0.0;
            let mut pick = SUCCESSORS - 1;
            for (i, &p) in PROBS.iter().enumerate() {
                acc += p;
                if u < acc {
                    pick = i;
                    break;
                }
            }
            cur = successors[cur as usize][pick];
        }
        MarkovCorpus {
            vocab,
            tokens,
            successors,
        }
    }

    /// Entropy rate of the transition distribution (nats/token),
    /// ignoring successor collisions — a lower bound on achievable loss.
    pub fn entropy_rate(&self) -> f64 {
        -PROBS.iter().map(|&p| p * p.ln()).sum::<f64>()
    }

    /// Log-likelihood (nats/token) of a window under the true chain —
    /// used in tests as the oracle for "how well can a model do".
    pub fn oracle_nll(&self, window: &[i32]) -> f64 {
        let mut total = 0.0;
        let mut count = 0usize;
        for w in window.windows(2) {
            let (a, b) = (w[0] as usize, w[1]);
            let mut p = 1e-9;
            for (i, &s) in self.successors[a].iter().enumerate() {
                if s == b {
                    p += PROBS[i];
                }
            }
            total -= p.ln();
            count += 1;
        }
        total / count.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let a = MarkovCorpus::generate(64, 1000, 7);
        let b = MarkovCorpus::generate(64, 1000, 7);
        assert_eq!(a.tokens, b.tokens);
        let c = MarkovCorpus::generate(64, 1000, 8);
        assert_ne!(a.tokens, c.tokens);
    }

    #[test]
    fn tokens_in_range() {
        let c = MarkovCorpus::generate(50, 5000, 1);
        assert!(c.tokens.iter().all(|&t| (0..50).contains(&t)));
        assert_eq!(c.tokens.len(), 5000);
    }

    #[test]
    fn has_low_entropy_structure() {
        let c = MarkovCorpus::generate(256, 20_000, 2);
        let h = c.entropy_rate();
        assert!(h < 1.5, "entropy rate {h}");
        // empirical check: oracle nll of the actual stream ≈ entropy rate
        let nll = c.oracle_nll(&c.tokens[..5000]);
        assert!(
            (nll - h).abs() < 0.3,
            "oracle nll {nll} far from entropy {h}"
        );
        // vastly below the uniform baseline
        assert!(nll < (256f64).ln() / 2.0);
    }

    #[test]
    fn all_tokens_appear_eventually() {
        let c = MarkovCorpus::generate(16, 50_000, 3);
        let mut seen = vec![false; 16];
        for &t in &c.tokens {
            seen[t as usize] = true;
        }
        let coverage = seen.iter().filter(|&&s| s).count();
        assert!(coverage >= 12, "only {coverage}/16 tokens reachable");
    }
}
