//! Run configuration: parses CLI flags / spec strings into a full
//! training job description.
//!
//! Policy spec grammar (the axes of Tables 1–3/6):
//!   "baseline"            — FSDP: FP32 weights, FP16 grads
//!   "exact"               — fully lossless: FP32 weights AND FP32 grads
//!                           (the transport-equivalence reference)
//!   "w8g8"                — QSDP uniform quantization, 8-bit W and G
//!   "w5g4"                — any bit pair in 1..=8; "32" opts a role out
//!                           of quantization and back into its baseline
//!                           stream: w32 = FP32 weights, g32 = the FP16
//!                           gradient stream FSDP actually ships (§6.1)
//!                           — only "exact" carries FP32 gradients
//!   "w5g4+learned"        — learned level tables for both
//!   suffix "+det"         — deterministic (round-to-nearest) gradients
//!   suffix "+block"       — block-wise symmetric scales (ZeRO++ style,
//!                           128-element blocks) instead of the bucketed
//!                           min–max grid; wins over "+learned"
//!
//! The collective transport is likewise data: `--fabric
//! lockstep|flat|async|socket|elastic` selects the
//! [`crate::collectives::Collective`] backend the trainer wires into
//! its parameter store (`async` is the threaded ring backend over byte
//! channels, [`crate::collectives::AsyncFabric`]; `socket` is the same
//! ring over real localhost TCP,
//! [`crate::collectives::SocketFabric`]; `elastic` is the
//! multi-process fabric behind `qsdp launch` — it needs a rendezvous
//! endpoint carried in [`FabricOptions::elastic`], so it is excluded
//! from [`FabricKind::ALL`] sweeps, which must build hermetically).
//! [`FabricOptions`] carries
//! the runtime knobs: `--fabric-persistent true|false` (async only;
//! default true: spawn the per-rank worker threads once, at fabric
//! construction, instead of per call), `--fabric-check-every N`
//! (release-build gather cross-check sampling period; 0 disables,
//! debug builds always check), and the socket transport's endpoint
//! flags `--fabric-addr IP` (bind address, default 127.0.0.1) and
//! `--fabric-port N` (base listen port, rank r gets N + r; default 0 =
//! kernel-assigned ephemeral ports). Socket construction can fail
//! (sandboxes may forbid loopback TCP), so backends are built through
//! the fallible [`FabricKind::try_build_with`]; the infallible
//! `build_with` survives for call sites that prefer a panic. Fabrics
//! are constructed **once per run** and reused across every step —
//! checkpoint restore re-shards parameters in place rather than
//! tearing down a running transport.

use crate::collectives::{AsyncFabric, Collective, FlatFabric, LockstepFabric, SocketFabric};
use crate::optim::AdamW;
use crate::quant::QuantPolicy;
use crate::runtime::elastic::ElasticFabric;
use crate::runtime::gpt::StepVariant;
use crate::sim::Topology;
use crate::util::args::Args;
use anyhow::{bail, Result};
use std::net::{IpAddr, Ipv4Addr, SocketAddr};

/// Which [`Collective`] transport backend a run uses.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FabricKind {
    /// Hierarchical two-level lockstep simulator (the paper's scheme).
    #[default]
    Lockstep,
    /// Flat all-pairs exchange (the ablation baseline).
    Flat,
    /// Threaded ring backend: one OS thread per rank, serialized
    /// messages over byte channels ([`AsyncFabric`]).
    Async,
    /// Threaded ring backend over real localhost TCP sockets with
    /// length-prefixed framing ([`SocketFabric`]).
    Socket,
    /// Multi-process elastic fabric: one OS process per rank under the
    /// `qsdp launch` supervisor, with epoch membership and fault
    /// recovery ([`ElasticFabric`]).
    Elastic,
}

impl FabricKind {
    /// Every *hermetically constructible* backend, in registry order —
    /// what the cross-fabric differential harness sweeps. The elastic
    /// backend is deliberately absent: it cannot be built from a
    /// `Topology` alone (it needs a live rendezvous endpoint and a
    /// rank identity), so sweeps that call `try_build` would always
    /// fail on it.
    pub const ALL: [FabricKind; 4] =
        [FabricKind::Lockstep, FabricKind::Flat, FabricKind::Async, FabricKind::Socket];

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "lockstep" | "hier" | "hierarchical" => FabricKind::Lockstep,
            "flat" => FabricKind::Flat,
            "async" | "ring" => FabricKind::Async,
            "socket" | "tcp" => FabricKind::Socket,
            "elastic" => FabricKind::Elastic,
            other => bail!("unknown fabric {other:?} (want lockstep|flat|async|socket|elastic)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            FabricKind::Lockstep => "lockstep",
            FabricKind::Flat => "flat",
            FabricKind::Async => "async",
            FabricKind::Socket => "socket",
            FabricKind::Elastic => "elastic",
        }
    }

    /// Does this backend move messages over a rank ring (as opposed to
    /// the lockstep one-NIC-at-a-time simulations)? Ring backends get
    /// the per-link contention clock
    /// ([`crate::sim::NetworkModel::ring_time`]) because their
    /// transfers genuinely overlap across links.
    pub fn is_ring(self) -> bool {
        matches!(self, FabricKind::Async | FabricKind::Socket | FabricKind::Elastic)
    }

    /// Construct the backend for a cluster with default options,
    /// surfacing construction failures (the socket backend needs
    /// loopback TCP) as errors.
    pub fn try_build(self, topo: Topology) -> Result<Box<dyn Collective>> {
        self.try_build_with(topo, FabricOptions::default())
    }

    /// Construct the backend for a cluster. `opts` only affects the
    /// message-passing backends (the lockstep simulators have no
    /// runtime). The socket backend opens its TCP ring here — once per
    /// run — and reports a clear error if the environment forbids it.
    pub fn try_build_with(self, topo: Topology, opts: FabricOptions) -> Result<Box<dyn Collective>> {
        Ok(match self {
            FabricKind::Lockstep => Box::new(LockstepFabric::new(topo)),
            FabricKind::Flat => Box::new(FlatFabric::new(topo)),
            FabricKind::Async => {
                Box::new(AsyncFabric::with_options(topo, opts.persistent, opts.check_every))
            }
            FabricKind::Socket => Box::new(SocketFabric::with_options(
                topo,
                opts.socket_addr,
                opts.socket_base_port,
                opts.check_every,
                std::time::Duration::from_millis(opts.stall_ms),
            )?),
            FabricKind::Elastic => {
                let peer = opts.elastic.ok_or_else(|| {
                    anyhow::anyhow!(
                        "the elastic fabric needs a rendezvous endpoint — run the job through \
                         `qsdp launch`, or pass --rank/--world/--rendezvous for a standalone rank"
                    )
                })?;
                Box::new(ElasticFabric::connect(topo, peer, opts.socket_addr, opts.check_every)?)
            }
        })
    }

    /// Infallible construction with default options; panics (with the
    /// underlying error) if the backend cannot be built here.
    pub fn build(self, topo: Topology) -> Box<dyn Collective> {
        self.build_with(topo, FabricOptions::default())
    }

    /// Infallible construction; panics (with the underlying error) if
    /// the backend cannot be built here. Prefer
    /// [`Self::try_build_with`] anywhere a `Result` can propagate.
    pub fn build_with(self, topo: Topology, opts: FabricOptions) -> Box<dyn Collective> {
        self.try_build_with(topo, opts)
            .unwrap_or_else(|e| panic!("failed to construct the {} fabric: {e}", self.name()))
    }
}

/// Runtime knobs for the message-passing transports (`--fabric async`
/// / `--fabric socket`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FabricOptions {
    /// Spawn the per-rank worker threads once at fabric construction
    /// (the persistent runtime) instead of per collective call. Async
    /// only — the socket backend is always persistent (its TCP ring is
    /// established once, at construction).
    pub persistent: bool,
    /// Release-build gather cross-check sampling period: verify the
    /// gathered tensor across all ranks every Nth call (0 = never;
    /// debug builds always check).
    pub check_every: u64,
    /// Bind address for the socket backend's per-rank listeners
    /// (`--fabric-addr`, default 127.0.0.1).
    pub socket_addr: IpAddr,
    /// Base TCP port for the socket backend: rank r listens on
    /// `socket_base_port + r`; 0 = kernel-assigned ephemeral ports
    /// (`--fabric-port`, default 0).
    pub socket_base_port: u16,
    /// Socket-backend stall deadline in milliseconds: a ring hop with
    /// no read/write progress for this long fails with a typed
    /// `Stalled` error instead of hanging (`--fabric-stall-ms`,
    /// default 60000; must be positive).
    pub stall_ms: u64,
    /// The elastic backend's per-rank identity and rendezvous
    /// endpoint; `None` (the default) for every in-process backend.
    /// Set programmatically by the elastic worker driver (the flags
    /// `--rank`/`--rendezvous` arrive through `runtime::elastic`, not
    /// through `RunConfig::from_args`).
    pub elastic: Option<ElasticPeer>,
}

impl Default for FabricOptions {
    fn default() -> Self {
        FabricOptions {
            persistent: true,
            check_every: crate::collectives::async_fabric::DEFAULT_CHECK_EVERY,
            socket_addr: IpAddr::V4(Ipv4Addr::LOCALHOST),
            socket_base_port: 0,
            stall_ms: 60_000,
            elastic: None,
        }
    }
}

/// One elastic rank's identity: who we are, where the rendezvous
/// lives, and the failure-detection/recovery timing knobs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ElasticPeer {
    /// This process's training rank in `0..world`.
    pub rank: usize,
    /// The rendezvous service (the `launch` supervisor's
    /// `RendezvousServer`, `--rendezvous` / `QSDP_RENDEZVOUS`).
    pub rendezvous: SocketAddr,
    /// Wire-ring stall limit in milliseconds: a peer silent for this
    /// long faults the collective and triggers recovery
    /// (`--stall-ms`).
    pub stall_ms: u64,
    /// How long to wait for the rendezvous to hand out an epoch before
    /// giving up (`--rendezvous-timeout-ms`). Must exceed the
    /// supervisor's restart backoff for re-admission to work.
    pub rendezvous_timeout_ms: u64,
    /// The newest checkpoint step this process can restore from —
    /// offered at every rendezvous so the round's `restore_step` is
    /// the minimum over members.
    pub ckpt_step: u64,
}

/// A fully-specified training job.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Artifact config name (nano/tiny/small/medium).
    pub model: String,
    pub policy: QuantPolicy,
    /// Whether to run the in-graph fake-quant step variant instead of
    /// quantizing on the communication path (cross-validation mode).
    pub variant: StepVariant,
    pub topo: Topology,
    pub steps: u64,
    pub warmup: u64,
    pub seed: u64,
    pub lr: f32,
    pub eval_every: u64,
    /// Learned-levels refresh steps (paper runs it at 400/1900/3800).
    pub learned_at: Vec<u64>,
    /// Corpus length in tokens.
    pub corpus_len: usize,
    /// Inter-node bandwidth (Gbps) for the simulated clock.
    pub inter_gbps: f64,
    /// Gradient-accumulation microbatches per optimizer step (the paper
    /// uses 4; weights are re-gathered per microbatch, which is exactly
    /// why FSDP's weight traffic dominates — Appendix B).
    pub n_accum: usize,
    /// Pipeline the per-tensor collectives through the non-blocking
    /// fabric API (`--overlap`): encode of tensor t+1 overlaps the wire
    /// of tensor t, and the simulated clock charges
    /// max(compute, comm) instead of their sum. Bit-identical loss
    /// trajectories to the sequential schedule.
    pub overlap: bool,
    /// Hierarchical two-level gradient reduce-scatter (`--hier`): 8-bit
    /// block-quantized intra-node hop, 4-bit cross-node hop, per-tensor
    /// error feedback carried across steps (ZeRO++/SDP4Bit recipe on
    /// top of QSDP's filter). Requires a quantized gradient policy.
    pub hier: bool,
    /// hpZ-style secondary weight partition (`--hpz`): after the first
    /// full gather of a step, repeat gathers (gradient accumulation)
    /// are served from an intra-node replica, so cross-node weight
    /// traffic is charged once per step instead of once per microbatch.
    pub hpz: bool,
    /// Collective transport backend.
    pub fabric: FabricKind,
    /// Async-transport runtime knobs (persistent workers, cross-check
    /// sampling rate).
    pub fabric_opts: FabricOptions,
}

impl RunConfig {
    pub fn from_args(args: &Args) -> Result<Self> {
        let model = args.str_or("config", "tiny");
        let policy = parse_policy(&args.str_or("policy", "w8g8"))?;
        let steps = args.u64_or("steps", 200);
        Ok(RunConfig {
            model,
            policy,
            variant: StepVariant::Plain,
            topo: Topology::new(
                args.usize_or("nodes", 2),
                args.usize_or("gpus-per-node", 2),
            ),
            steps,
            warmup: args.u64_or("warmup", steps / 10),
            seed: args.u64_or("seed", 7),
            lr: args.f64_or("lr", 6e-4) as f32,
            eval_every: args.u64_or("eval-every", 50),
            learned_at: vec![],
            corpus_len: args.usize_or("corpus-len", 200_000),
            inter_gbps: args.f64_or("bandwidth", 10.0),
            n_accum: args.usize_or("accum", 1),
            overlap: args.bool_or("overlap", false),
            hier: args.bool_or("hier", false),
            hpz: args.bool_or("hpz", false),
            fabric: FabricKind::parse(&args.str_or("fabric", "lockstep"))?,
            fabric_opts: FabricOptions {
                persistent: args.bool_or("fabric-persistent", true),
                check_every: args.u64_or(
                    "fabric-check-every",
                    crate::collectives::async_fabric::DEFAULT_CHECK_EVERY,
                ),
                socket_addr: {
                    let s = args.str_or("fabric-addr", "127.0.0.1");
                    s.parse().map_err(|_| {
                        anyhow::anyhow!("--fabric-addr expects an IP address, got {s:?}")
                    })?
                },
                socket_base_port: u16::try_from(args.u64_or("fabric-port", 0)).map_err(|_| {
                    anyhow::anyhow!("--fabric-port expects a port number below 65536")
                })?,
                stall_ms: {
                    let ms = args.u64_or("fabric-stall-ms", 60_000);
                    if ms == 0 {
                        bail!("--fabric-stall-ms must be positive (a 0 deadline would \
                               fail every ring hop immediately)");
                    }
                    ms
                },
                elastic: None,
            },
        })
    }

    pub fn optimizer(&self) -> AdamW {
        AdamW::paper(self.lr)
    }
}

/// Parse a policy spec string (see module docs).
pub fn parse_policy(spec: &str) -> Result<QuantPolicy> {
    let mut parts = spec.split('+');
    let base = parts.next().unwrap_or("");
    let mut learned = false;
    let mut det = false;
    let mut block = false;
    for ext in parts {
        match ext {
            "learned" => learned = true,
            "det" => det = true,
            "block" => block = true,
            other => bail!("unknown policy suffix {other:?}"),
        }
    }
    let mut policy = if base == "baseline" || base == "fsdp" {
        QuantPolicy::baseline()
    } else if base == "exact" {
        QuantPolicy::exact()
    } else {
        let rest = base
            .strip_prefix('w')
            .ok_or_else(|| anyhow::anyhow!("bad policy spec {spec:?} (want e.g. w8g8)"))?;
        let (w, g) = rest
            .split_once('g')
            .ok_or_else(|| anyhow::anyhow!("bad policy spec {spec:?} (want e.g. w8g8)"))?;
        let wb: u32 = w.parse()?;
        let gb: u32 = g.parse()?;
        let mut p = QuantPolicy::baseline();
        if wb < 32 {
            p.weight_bits = Some(u8::try_from(wb).ok().filter(|b| (1..=8).contains(b))
                .ok_or_else(|| anyhow::anyhow!("weight bits {wb} out of range (1..=8 or 32)"))?);
        }
        if gb < 32 {
            p.grad_bits = Some(u8::try_from(gb).ok().filter(|b| (1..=8).contains(b))
                .ok_or_else(|| anyhow::anyhow!("grad bits {gb} out of range (1..=8 or 32)"))?);
        }
        p.stochastic_grads = true;
        p
    };
    if det {
        policy.stochastic_grads = false;
    }
    if learned {
        use crate::quant::LearnedLevels;
        if let Some(b) = policy.weight_bits {
            policy.learned_weights = Some(LearnedLevels::uniform(b));
        }
        if let Some(b) = policy.grad_bits {
            policy.learned_grads = Some(LearnedLevels::uniform(b));
        }
    }
    if block {
        if policy.is_baseline() {
            bail!("+block needs a quantized policy (e.g. w8g8+block), got {spec:?}");
        }
        policy.block = Some(crate::quant::DEFAULT_BLOCK);
    }
    Ok(policy)
}

/// Render a policy back to its spec string (for logs/tables).
pub fn policy_name(p: &QuantPolicy) -> String {
    if p.is_baseline() {
        return if p.exact_grads { "exact" } else { "baseline" }.into();
    }
    let w = p.weight_bits.map(|b| b.to_string()).unwrap_or("32".into());
    let g = p.grad_bits.map(|b| b.to_string()).unwrap_or("32".into());
    let mut s = format!("w{w}g{g}");
    if p.learned_weights.is_some() || p.learned_grads.is_some() {
        s.push_str("+learned");
    }
    if p.block.is_some() {
        s.push_str("+block");
    }
    if p.grad_bits.is_some() && !p.stochastic_grads {
        s.push_str("+det");
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_baseline() {
        let p = parse_policy("baseline").unwrap();
        assert!(p.is_baseline());
        assert_eq!(policy_name(&p), "baseline");
    }

    #[test]
    fn parses_exact() {
        let p = parse_policy("exact").unwrap();
        assert!(p.is_baseline());
        assert!(p.exact_grads);
        assert_eq!(policy_name(&p), "exact");
        use crate::model::spec::ParamKind;
        use crate::quant::{Codec, TensorRole};
        assert_eq!(p.codec(TensorRole::Grad, ParamKind::Matrix).name(), "fp32");
    }

    #[test]
    fn parses_bit_pairs() {
        let p = parse_policy("w8g8").unwrap();
        assert_eq!(p.weight_bits, Some(8));
        assert_eq!(p.grad_bits, Some(8));
        let p = parse_policy("w5g4").unwrap();
        assert_eq!(p.weight_bits, Some(5));
        assert_eq!(p.grad_bits, Some(4));
        assert_eq!(policy_name(&p), "w5g4");
    }

    #[test]
    fn parses_32_as_uncompressed() {
        let p = parse_policy("w4g32").unwrap();
        assert_eq!(p.weight_bits, Some(4));
        assert_eq!(p.grad_bits, None);
        let p = parse_policy("w32g3").unwrap();
        assert_eq!(p.weight_bits, None);
        assert_eq!(p.grad_bits, Some(3));
    }

    #[test]
    fn parses_learned_suffix() {
        let p = parse_policy("w5g4+learned").unwrap();
        assert!(p.learned_weights.is_some());
        assert!(p.learned_grads.is_some());
        assert_eq!(p.learned_weights.as_ref().unwrap().bits, 5);
        assert_eq!(policy_name(&p), "w5g4+learned");
    }

    #[test]
    fn det_suffix() {
        let p = parse_policy("w8g8+det").unwrap();
        assert!(!p.stochastic_grads);
        // the label must distinguish det runs from stochastic ones
        assert_eq!(policy_name(&p), "w8g8+det");
        let p = parse_policy("w4g4+learned+det").unwrap();
        assert_eq!(policy_name(&p), "w4g4+learned+det");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_policy("x9").is_err());
        assert!(parse_policy("w9g9").is_err());
        assert!(parse_policy("w8g8+foo").is_err());
        assert!(parse_policy("w0g4").is_err());
    }

    #[test]
    fn block_suffix_parses_and_roundtrips() {
        let p = parse_policy("w8g8+block").unwrap();
        assert_eq!(p.block, Some(crate::quant::DEFAULT_BLOCK));
        assert_eq!(policy_name(&p), "w8g8+block");
        // composes with +det, and the name orders the suffixes stably
        let p = parse_policy("w4g4+block+det").unwrap();
        assert_eq!(p.block, Some(crate::quant::DEFAULT_BLOCK));
        assert!(!p.stochastic_grads);
        assert_eq!(policy_name(&p), "w4g4+block+det");
        // a policy with nothing quantized has no blocks to scale
        assert!(parse_policy("baseline+block").is_err());
        assert!(parse_policy("exact+block").is_err());
    }

    #[test]
    fn hier_and_hpz_flags_parse() {
        let a = Args::parse("train".split_whitespace().map(|s| s.to_string()));
        let c = RunConfig::from_args(&a).unwrap();
        assert!(!c.hier && !c.hpz, "hierarchical paths must be opt-in");
        let a = Args::parse(
            "train --hier --hpz --policy w8g8".split_whitespace().map(|s| s.to_string()),
        );
        let c = RunConfig::from_args(&a).unwrap();
        assert!(c.hier);
        assert!(c.hpz);
    }

    #[test]
    fn run_config_from_args() {
        let a = Args::parse(
            "train --config nano --policy w4g4 --steps 10 --nodes 1 --gpus-per-node 2"
                .split_whitespace()
                .map(|s| s.to_string()),
        );
        let c = RunConfig::from_args(&a).unwrap();
        assert_eq!(c.model, "nano");
        assert_eq!(c.topo.world(), 2);
        assert_eq!(c.steps, 10);
        assert_eq!(c.policy.weight_bits, Some(4));
        assert_eq!(c.fabric, FabricKind::Lockstep);
    }

    #[test]
    fn fabric_kind_parses_and_builds() {
        assert_eq!(FabricKind::parse("lockstep").unwrap(), FabricKind::Lockstep);
        assert_eq!(FabricKind::parse("hier").unwrap(), FabricKind::Lockstep);
        assert_eq!(FabricKind::parse("flat").unwrap(), FabricKind::Flat);
        assert_eq!(FabricKind::parse("async").unwrap(), FabricKind::Async);
        assert_eq!(FabricKind::parse("ring").unwrap(), FabricKind::Async);
        assert_eq!(FabricKind::parse("socket").unwrap(), FabricKind::Socket);
        assert_eq!(FabricKind::parse("tcp").unwrap(), FabricKind::Socket);
        assert!(FabricKind::parse("mesh").is_err());
        assert!(FabricKind::Socket.is_ring() && FabricKind::Async.is_ring());
        assert!(!FabricKind::Lockstep.is_ring() && !FabricKind::Flat.is_ring());
        let topo = Topology::new(2, 2);
        for kind in FabricKind::ALL {
            if kind == FabricKind::Socket && !crate::collectives::loopback_available() {
                eprintln!("SKIP: socket fabric build (loopback TCP unavailable in this sandbox)");
                continue;
            }
            let fabric = kind.try_build(topo).unwrap();
            assert_eq!(fabric.name(), kind.name());
            assert_eq!(fabric.topo(), topo);
        }
        let a = Args::parse(
            "train --fabric flat".split_whitespace().map(|s| s.to_string()),
        );
        assert_eq!(RunConfig::from_args(&a).unwrap().fabric, FabricKind::Flat);
        let a = Args::parse(
            "train --fabric async".split_whitespace().map(|s| s.to_string()),
        );
        assert_eq!(RunConfig::from_args(&a).unwrap().fabric, FabricKind::Async);
    }

    #[test]
    fn fabric_options_flags_parse_and_build() {
        // defaults: persistent runtime, sampled release cross-check
        let a = Args::parse("train".split_whitespace().map(|s| s.to_string()));
        let c = RunConfig::from_args(&a).unwrap();
        assert_eq!(c.fabric_opts, FabricOptions::default());
        assert!(c.fabric_opts.persistent);
        assert!(c.fabric_opts.check_every > 0);
        // explicit overrides
        let a = Args::parse(
            "train --fabric async --fabric-persistent false --fabric-check-every 7"
                .split_whitespace()
                .map(|s| s.to_string()),
        );
        let c = RunConfig::from_args(&a).unwrap();
        assert!(!c.fabric_opts.persistent);
        assert_eq!(c.fabric_opts.check_every, 7);
        let fabric = c.fabric.build_with(c.topo, c.fabric_opts);
        assert_eq!(fabric.name(), "async");
        assert_eq!(fabric.topo(), c.topo);
    }

    #[test]
    fn fabric_stall_ms_flag_parses_and_rejects_zero() {
        let a = Args::parse("train".split_whitespace().map(|s| s.to_string()));
        let c = RunConfig::from_args(&a).unwrap();
        assert_eq!(c.fabric_opts.stall_ms, 60_000, "default matches the old hard-coded limit");
        let a = Args::parse(
            "train --fabric socket --fabric-stall-ms 2500"
                .split_whitespace()
                .map(|s| s.to_string()),
        );
        let c = RunConfig::from_args(&a).unwrap();
        assert_eq!(c.fabric_opts.stall_ms, 2500);
        let a = Args::parse(
            "train --fabric-stall-ms 0".split_whitespace().map(|s| s.to_string()),
        );
        let err = RunConfig::from_args(&a).expect_err("a zero stall deadline is rejected");
        assert!(format!("{err:#}").contains("fabric-stall-ms"), "error names the flag: {err:#}");
    }

    #[test]
    fn elastic_fabric_kind_parses_but_needs_a_rendezvous() {
        assert_eq!(FabricKind::parse("elastic").unwrap(), FabricKind::Elastic);
        assert_eq!(FabricKind::Elastic.name(), "elastic");
        assert!(FabricKind::Elastic.is_ring(), "elastic uses the ring contention clock");
        // Deliberately not in ALL: the differential sweeps build every
        // entry hermetically, and elastic needs a live rendezvous.
        assert!(!FabricKind::ALL.contains(&FabricKind::Elastic));
        let err = FabricKind::Elastic
            .try_build(Topology::new(2, 1))
            .expect_err("building without a rendezvous endpoint must fail");
        let msg = format!("{err:#}");
        assert!(msg.contains("rendezvous"), "error must say what is missing: {msg}");
        // and the default options carry no peer identity
        assert_eq!(FabricOptions::default().elastic, None);
    }

    #[test]
    fn socket_fabric_flags_parse() {
        // defaults: loopback, ephemeral ports
        let a = Args::parse("train".split_whitespace().map(|s| s.to_string()));
        let c = RunConfig::from_args(&a).unwrap();
        assert_eq!(c.fabric_opts.socket_addr, IpAddr::V4(Ipv4Addr::LOCALHOST));
        assert_eq!(c.fabric_opts.socket_base_port, 0);
        // explicit endpoint
        let a = Args::parse(
            "train --fabric socket --fabric-addr 127.0.0.1 --fabric-port 39000"
                .split_whitespace()
                .map(|s| s.to_string()),
        );
        let c = RunConfig::from_args(&a).unwrap();
        assert_eq!(c.fabric, FabricKind::Socket);
        assert_eq!(c.fabric_opts.socket_base_port, 39000);
        assert_eq!(c.fabric_opts.socket_addr, "127.0.0.1".parse::<IpAddr>().unwrap());
        // malformed endpoint flags fail loudly, not silently
        let a = Args::parse(
            "train --fabric-addr nonsense".split_whitespace().map(|s| s.to_string()),
        );
        assert!(RunConfig::from_args(&a).is_err());
        let a = Args::parse(
            "train --fabric-port 70000".split_whitespace().map(|s| s.to_string()),
        );
        assert!(RunConfig::from_args(&a).is_err());
    }
}
