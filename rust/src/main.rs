//! QSDP command-line interface.
//!
//! Subcommands map 1:1 to the paper's experiments (DESIGN.md §5):
//!   train      — run one training job (FSDP baseline or QSDP)
//!   launch     — supervise P worker processes over the elastic fabric
//!   smoke      — elastic smoke job / its in-process reference digest
//!   chaos      — seeded fault-injection soak over the fabric stack
//!   table1..6  — regenerate the paper's tables
//!   figure3/4/6/7 — regenerate the paper's figures
//!   theory     — Theorem 2 / Corollary 3 convergence validation
//!   reproduce  — run everything, writing results/ CSVs
//!   info       — print artifact/config inventory
//!   lint       — self-enforcing static analysis (fabric safety contracts)

use qsdp::experiments;
use qsdp::util::args::Args;

// Every `--flag` named below must have a live parse site and every
// flag `config::RunConfig` parses must be named below — `qsdp lint`
// rule `flag-usage` cross-checks both directions on each cargo test.
fn usage() -> ! {
    eprintln!(
        "usage: qsdp <command> [flags]\n\
         commands:\n  \
         train     --config tiny --policy w8g8|baseline|exact --steps N\n            \
         --nodes N --gpus-per-node G [--warmup N --seed S --lr F]\n            \
         [--eval-every N --corpus-len N --bandwidth GBPS --accum K]\n            \
         --fabric lockstep|flat|async|socket [--fabric-addr IP] [--fabric-port N]\n            \
         [--fabric-persistent B --fabric-check-every N --fabric-stall-ms MS]\n            \
         [--overlap] [--hier] [--hpz]  (pipeline collectives; two-level quant)\n  \
         launch    --world P [--nodes N --gpus-per-node G] [--max-restarts K]\n            \
         [--ckpt-dir DIR --ckpt-every K] [--launch-timeout-s S]\n            \
         <train|smoke>  (elastic multi-process run)\n  \
         smoke     [--world P --iters N --seed S]  (reference digest; worker mode via --rank)\n  \
         chaos     [--seeds N | --seed S] [--skip-if-no-loopback]  (seeded fault soak)\n  \
         lint      [--json] [--root DIR]  (static-analysis contracts; exit 1 on findings)\n  \
         table1 | table2 | table3 | table5 | table6\n  \
         figure3 | figure4 | figure6 | figure7\n  \
         theory    [--dim N] [--kappa K]\n  \
         ablations [--steps N]\n  \
         reproduce [--steps N]\n  \
         info"
    );
    std::process::exit(2);
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    match cmd {
        "train" => experiments::cmd_train(&args),
        "launch" => qsdp::runtime::elastic::cmd_launch(&args),
        "smoke" => qsdp::runtime::elastic::cmd_smoke(&args),
        "chaos" => qsdp::faults::chaos::cmd_chaos(&args),
        "lint" => qsdp::analysis::cmd_lint(&args),
        "table1" => experiments::table1(&args),
        "table2" => experiments::table2(&args),
        "table3" => experiments::table3(&args),
        "table5" => experiments::table5(&args),
        "table6" => experiments::table6(&args),
        "figure3" => experiments::figure3(&args),
        "figure4" => experiments::figure4(&args),
        "figure6" => experiments::figure6(&args),
        "figure7" => experiments::figure7(&args),
        "theory" => experiments::cmd_theory(&args),
        "ablations" => experiments::ablations(&args),
        "reproduce" => experiments::reproduce(&args),
        "info" => experiments::info(&args),
        _ => usage(),
    }
}
