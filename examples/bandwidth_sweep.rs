//! Bandwidth sweep (paper Figures 4 & 6): step time vs inter-node
//! bandwidth for the paper's model sizes, FSDP vs QSDP vs fake
//! compression, using the analytic cluster model over byte-exact
//! quantized payload sizes.
//!
//! ```sh
//! cargo run --release --example bandwidth_sweep
//! cargo run --release --example bandwidth_sweep -- --model gpt1.3b --fine
//! ```

use anyhow::Result;
use qsdp::quant::QuantPolicy;
use qsdp::sim::StepTimeModel;
use qsdp::util::args::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let models: Vec<String> = if let Some(m) = args.get("model") {
        vec![m.to_string()]
    } else {
        ["gpt125m", "gpt350m", "gpt1.3b"].iter().map(|s| s.to_string()).collect()
    };
    let bws: Vec<f64> = if args.bool_or("fine", false) {
        vec![5.0, 10.0, 20.0, 30.0, 50.0, 75.0, 100.0]
    } else {
        vec![10.0, 50.0, 100.0]
    };
    let fsdp = QuantPolicy::baseline();
    let qsdp = QuantPolicy::qsdp_default();

    for m in &models {
        println!("== {m} ==");
        println!(
            "{:>8} {:>10} {:>10} {:>10} {:>10} {:>9}",
            "Gbps", "FSDP", "QSDP", "fake8x", "ideal", "speedup"
        );
        for &bw in &bws {
            let model = StepTimeModel::paper(m, bw).expect("paper model");
            let f = model.step_total(&fsdp);
            let q = model.step_total(&qsdp);
            let fake8 = model.fake_total(8.0, 8.0);
            let ideal = model.fake_total(1e12, 1e12);
            println!(
                "{bw:>8.0} {f:>9.2}s {q:>9.2}s {fake8:>9.2}s {ideal:>9.2}s {:>8.2}x",
                f / q
            );
        }
        // breakdown at 10 Gbps
        let model = StepTimeModel::paper(m, 10.0).unwrap();
        let b = model.step(&fsdp);
        println!(
            "   FSDP@10G breakdown: compute {:.2}s, weight comm {:.2}s, grad comm {:.2}s",
            b.compute_s, b.weight_comm_s, b.grad_comm_s
        );
    }
    println!("(paper: QSDP essentially flat across bandwidths; 2.2x end-to-end at 10 Gbps for 1.3B)");
    Ok(())
}
