//! Quickstart: the two surfaces of the crate in one file.
//!
//! 1. The **Codec / Collective API** — encode a tensor with the codec a
//!    [`QuantPolicy`] resolves, push it through registered fabrics
//!    (`lockstep` hierarchical, `flat` all-pairs, `async` — the
//!    threaded ring backend that moves real serialized bytes between
//!    per-rank OS threads — and `socket`, the same ring over real
//!    localhost TCP; select one at the CLI with
//!    `--fabric lockstep|flat|async|socket`), and read the byte-exact
//!    traffic ledger. This part runs with no artifacts.
//! 2. The **trainer** — a tiny GPT with QSDP (W8G8) on 4 simulated
//!    workers for 30 steps vs the FSDP baseline (needs `make
//!    artifacts` and the real PJRT backend).
//!
//! Run with:
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use qsdp::collectives::{AsyncFabric, Collective, FlatFabric, LockstepFabric, TrafficLedger};
use qsdp::config::{parse_policy, FabricKind, RunConfig};
use qsdp::coordinator::{Trainer, TrainerOptions};
use qsdp::model::spec::artifacts_root;
use qsdp::model::ParamKind;
use qsdp::quant::{Codec, EncodedTensor, QuantPolicy, TensorRole};
use qsdp::runtime::Engine;
use qsdp::sim::Topology;
use qsdp::util::Pcg64;
use std::sync::Arc;

/// Tour the trait API: policy → codec → encoded message → fabric.
fn codec_and_fabric_tour() {
    let topo = Topology::new(2, 2); // 2 nodes x 2 GPUs
    let policy = QuantPolicy::qsdp_default(); // W8G8, bucket 1024
    let mut rng = Pcg64::seeded(7);
    let mut tensor = vec![0.0f32; 1 << 16];
    rng.fill_normal(&mut tensor, 0.02);

    // (1) the policy resolves a codec per (role, tensor-kind) pair
    let wcodec = policy.codec(TensorRole::Weight, ParamKind::Matrix);
    let e = wcodec.encode(&tensor, &mut rng);
    println!(
        "weight codec '{}' : {} elems -> {} wire bytes ({:.2}x vs fp32), analytic {}",
        wcodec.name(),
        e.n,
        e.byte_size(),
        e.ratio(),
        wcodec.wire_bytes(tensor.len()),
    );

    // (2) collectives are backends implementing the Collective trait —
    // same data, different traffic pattern. `async` runs one OS thread
    // per rank and ships these exact bytes over channels; all three
    // decode to the identical gathered tensor.
    let shards: Vec<EncodedTensor> = (0..topo.world())
        .map(|r| wcodec.encode(&tensor[topo.shard_range(tensor.len(), r)], &mut rng))
        .collect();
    let lock = LockstepFabric::new(topo);
    let flat = FlatFabric::new(topo);
    let aring = AsyncFabric::new(topo);
    let fabrics: [&dyn Collective; 3] = [&lock, &flat, &aring];
    for fabric in fabrics {
        let mut ledger = TrafficLedger::new();
        let gathered = fabric.all_gather(&shards, &mut ledger);
        println!(
            "all_gather on {:8} : {} elems | inter {:6.1} KiB | intra {:6.1} KiB",
            fabric.name(),
            gathered.len(),
            ledger.inter_bytes as f64 / 1024.0,
            ledger.intra_bytes as f64 / 1024.0,
        );
    }
}

fn run(policy: &str, engine: Arc<Engine>) -> Result<()> {
    let cfg = RunConfig {
        model: "nano".into(),
        policy: parse_policy(policy)?,
        variant: qsdp::runtime::gpt::StepVariant::Plain,
        topo: Topology::new(2, 2), // 2 nodes x 2 GPUs
        steps: 30,
        warmup: 3,
        seed: 7,
        lr: 3e-3,
        eval_every: 10,
        learned_at: vec![],
        corpus_len: 100_000,
        inter_gbps: 10.0,
        n_accum: 1,
        fabric: FabricKind::Lockstep,
        fabric_opts: qsdp::config::FabricOptions::default(),
    };
    let mut tr = Trainer::new(engine, &artifacts_root(), cfg, TrainerOptions { log_every: 10 })?;
    tr.run(30)?;
    println!(
        "[{policy:9}] loss {:.3} -> {:.3} | ppl {:.1} | sim time {:.2}s | inter-node traffic {:.1} MiB",
        tr.log.steps[0].loss,
        tr.log.final_loss(5),
        tr.log.final_ppl(5),
        tr.log.total_sim_s(),
        tr.log.total_inter_bytes() as f64 / (1 << 20) as f64,
    );
    Ok(())
}

fn main() -> Result<()> {
    codec_and_fabric_tour();
    let engine = Arc::new(Engine::cpu()?);
    println!("platform: {}", engine.platform());
    run("baseline", engine.clone())?;
    run("w8g8", engine)?;
    println!("note: same loss trajectory, a fraction of the traffic — that is QSDP.");
    Ok(())
}
