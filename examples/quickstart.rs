//! Quickstart: train a tiny GPT with QSDP (W8G8) on 4 simulated
//! workers for 30 steps and compare against the FSDP baseline.
//!
//! Run with:
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use qsdp::config::{parse_policy, RunConfig};
use qsdp::coordinator::{Trainer, TrainerOptions};
use qsdp::model::spec::artifacts_root;
use qsdp::runtime::Engine;
use qsdp::sim::Topology;
use std::sync::Arc;

fn run(policy: &str, engine: Arc<Engine>) -> Result<()> {
    let cfg = RunConfig {
        model: "nano".into(),
        policy: parse_policy(policy)?,
        variant: qsdp::runtime::gpt::StepVariant::Plain,
        topo: Topology::new(2, 2), // 2 nodes x 2 GPUs
        steps: 30,
        warmup: 3,
        seed: 7,
        lr: 3e-3,
        eval_every: 10,
        learned_at: vec![],
        corpus_len: 100_000,
        inter_gbps: 10.0,
        n_accum: 1,
    };
    let mut tr = Trainer::new(engine, &artifacts_root(), cfg, TrainerOptions { log_every: 10 })?;
    tr.run(30)?;
    println!(
        "[{policy:9}] loss {:.3} -> {:.3} | ppl {:.1} | sim time {:.2}s | inter-node traffic {:.1} MiB",
        tr.log.steps[0].loss,
        tr.log.final_loss(5),
        tr.log.final_ppl(5),
        tr.log.total_sim_s(),
        tr.log.total_inter_bytes() as f64 / (1 << 20) as f64,
    );
    Ok(())
}

fn main() -> Result<()> {
    let engine = Arc::new(Engine::cpu()?);
    println!("platform: {}", engine.platform());
    run("baseline", engine.clone())?;
    run("w8g8", engine)?;
    println!("note: same loss trajectory, a fraction of the traffic — that is QSDP.");
    Ok(())
}
