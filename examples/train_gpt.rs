//! End-to-end driver: pre-train a GPT on the synthetic corpus with QSDP
//! and log the loss curve (recorded in EXPERIMENTS.md §End-to-end).
//!
//! Defaults: the `tiny` config (≈ 0.9 M params) for 300 steps on a
//! 2×2 simulated cluster at 10 Gbps with W8G8 quantization. Flags:
//!   --config tiny|small|medium   --steps N   --policy w8g8|baseline|...
//!   --lr F   --nodes N --gpus-per-node G   --bandwidth Gbps
//!
//! ```sh
//! cargo run --release --example train_gpt -- --config tiny --steps 300
//! ```

use anyhow::Result;
use qsdp::config::{policy_name, RunConfig};
use qsdp::coordinator::{Trainer, TrainerOptions};
use qsdp::model::spec::artifacts_root;
use qsdp::runtime::Engine;
use qsdp::util::args::Args;
use std::sync::Arc;

fn main() -> Result<()> {
    let mut args = Args::from_env();
    // example-specific defaults
    if args.get("config").is_none() {
        args = Args::parse(
            std::env::args()
                .skip(1)
                .chain(["--config".into(), "tiny".into()]),
        );
    }
    let mut cfg = RunConfig::from_args(&args)?;
    cfg.steps = args.u64_or("steps", 300);
    cfg.lr = args.f64_or("lr", 3e-3) as f32;
    cfg.eval_every = args.u64_or("eval-every", 25);
    let policy = policy_name(&cfg.policy);
    eprintln!(
        "training {} with {} on {}x{} cluster @ {} Gbps, {} steps",
        cfg.model, policy, cfg.topo.nodes, cfg.topo.gpus_per_node, cfg.inter_gbps, cfg.steps
    );

    let engine = Arc::new(Engine::cpu()?);
    let mut tr = Trainer::new(
        engine,
        &artifacts_root(),
        cfg.clone(),
        TrainerOptions { log_every: 10 },
    )?;
    let t0 = std::time::Instant::now();
    tr.run(cfg.steps)?;
    let eval = tr.eval()?;
    tr.log.push_eval(tr.steps_done(), eval as f64);

    let csv = format!("results/train_gpt_{}_{}.csv", cfg.model, policy);
    tr.log.write_csv(&csv)?;
    println!("---");
    println!("model            : {} ({} params)", cfg.model, tr.dims().n_params());
    println!("policy           : {policy}");
    println!("steps            : {}", cfg.steps);
    println!("initial loss     : {:.4}", tr.log.steps[0].loss);
    println!("final train loss : {:.4}", tr.log.final_loss(10));
    println!("final eval loss  : {:.4}  (ppl {:.2})", eval, (eval as f64).exp());
    println!("host wall time   : {:.1}s", t0.elapsed().as_secs_f64());
    println!("simulated time   : {:.1}s", tr.log.total_sim_s());
    println!(
        "inter-node bytes : {:.1} MiB",
        tr.log.total_inter_bytes() as f64 / (1 << 20) as f64
    );
    println!("loss curve       : {csv}");
    Ok(())
}
