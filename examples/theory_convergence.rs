//! Theorem 2 / Corollary 3 in action on the PL testbed: the quantized
//! iteration converges linearly to (within ε of) the best lattice
//! point; violating the δ bound stalls; gradient quantization trades
//! variance for bits exactly as Corollary 3 predicts.
//!
//! ```sh
//! cargo run --release --example theory_convergence
//! ```

use qsdp::quant::MinMaxQuantizer;
use qsdp::theory::{theorem2_delta, PlQuadratic, QsgdIteration};
use qsdp::util::{args::Args, Pcg64};

fn main() {
    let args = Args::from_env();
    let dim = args.usize_or("dim", 64);
    let steps = args.usize_or("steps", 600);
    let (alpha, beta) = (1.0f32, args.f64_or("kappa", 4.0) as f32);
    let f = PlQuadratic::new(dim, alpha, beta, 42);
    let delta_star = 0.05f32;
    let mut rng = Pcg64::seeded(1);
    let bench = f.expected_best_on_lattice(delta_star, &mut rng, 1000);
    println!(
        "dim {dim}, condition β/α = {beta}, δ* = {delta_star}; benchmark E f(x*_r,δ*) = {bench:.3e}\n"
    );

    let x0 = vec![0.0f32; dim];
    let runs: Vec<(&str, QsgdIteration)> = vec![
        (
            "Theorem-2 δ, exact grads",
            QsgdIteration {
                eta: 1.0,
                delta: theorem2_delta(1.0, alpha, beta, delta_star),
                grad_quant: None,
                sigma: 0.0,
            },
        ),
        (
            "Theorem-2 δ, noisy grads (σ=0.5)",
            QsgdIteration {
                eta: 0.3,
                delta: theorem2_delta(0.3, alpha, beta, delta_star),
                grad_quant: None,
                sigma: 0.5,
            },
        ),
        (
            "Corollary-3: + 4-bit grad quant",
            QsgdIteration {
                eta: 0.3,
                delta: theorem2_delta(0.3, alpha, beta, delta_star),
                grad_quant: Some(MinMaxQuantizer::new(4, 64, true)),
                sigma: 0.5,
            },
        ),
        (
            "coarse δ = δ* (violates bound)",
            QsgdIteration {
                eta: 1.0,
                delta: delta_star,
                grad_quant: None,
                sigma: 0.0,
            },
        ),
    ];
    for (label, it) in runs {
        let tr = it.run(&f, &x0, steps, &mut rng);
        print!("{label:36} f: ");
        for &t in &[0usize, 10, 50, 100, steps] {
            print!("{:>9.2e} ", tr.f_vals[t.min(tr.f_vals.len() - 1)]);
        }
        let final_f = tr.f_vals.last().unwrap();
        let verdict = if *final_f <= bench + 1e-3 {
            "reaches lattice benchmark"
        } else {
            "stalls above benchmark"
        };
        println!("  [{verdict}]");
    }
    println!("\n(columns: f(x_t) at t = 0, 10, 50, 100, T)");
}
