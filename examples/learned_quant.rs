//! Learned quantization levels (paper §5.2, Algorithm 2; Tables 3/6,
//! Figures 7/8): fit level locations on real weight snapshots and show
//! the compression-error gap vs the uniform grid across bit-widths.
//!
//! ```sh
//! cargo run --release --example learned_quant -- --steps 60
//! ```

use anyhow::Result;
use qsdp::config::RunConfig;
use qsdp::coordinator::{Trainer, TrainerOptions};
use qsdp::model::spec::artifacts_root;
use qsdp::quant::{learned::normalize_bucketwise, LearnedLevels, MinMaxQuantizer, QuantPolicy};
use qsdp::runtime::Engine;
use qsdp::sim::Topology;
use qsdp::util::{args::Args, stats::rel_l2_err, Pcg64};
use std::sync::Arc;

fn main() -> Result<()> {
    let args = Args::from_env();
    let steps = args.u64_or("steps", 60);
    // Train a nano model briefly so the weights have real structure.
    let mut cfg = RunConfig::from_args(&args)?;
    cfg.model = args.str_or("config", "nano");
    cfg.policy = QuantPolicy::wg(5, 4);
    cfg.topo = Topology::new(2, 1);
    cfg.steps = steps;
    cfg.warmup = steps / 10;
    cfg.lr = 3e-3;
    cfg.eval_every = 0;
    let engine = Arc::new(Engine::cpu()?);
    let mut tr = Trainer::new(engine, &artifacts_root(), cfg, TrainerOptions::default())?;
    eprintln!("warming up weights with {steps} training steps...");
    tr.run(steps)?;
    let master = tr.master_params();
    let specs = tr.dims().param_spec();

    let bucket = 1024;
    let mut rng = Pcg64::seeded(5);
    println!(
        "{:<16} {:>4} {:>12} {:>12} {:>8}",
        "layer", "bits", "uniform_err", "learned_err", "gain"
    );
    for (spec, w) in specs.iter().zip(&master) {
        if spec.kind != qsdp::model::ParamKind::Matrix || w.len() < 2048 {
            continue;
        }
        for bits in [3u8, 4, 5, 6] {
            let mut u = w.clone();
            MinMaxQuantizer::new(bits, bucket, false).apply(&mut u, &mut rng);
            let eu = rel_l2_err(&u, w);
            let mut ll = LearnedLevels::uniform(bits);
            let mses = ll.fit(&normalize_bucketwise(w, bucket), 0.01, 8);
            let mut l = w.clone();
            ll.apply(&mut l, bucket);
            let el = rel_l2_err(&l, w);
            println!(
                "{:<16} {:>4} {:>12.5} {:>12.5} {:>7.2}x  (fit mse {:.2e} -> {:.2e})",
                spec.name,
                bits,
                eu,
                el,
                eu / el.max(1e-12),
                mses.first().unwrap(),
                mses.last().unwrap()
            );
        }
    }
    println!("\n(paper Figures 7/8: learned error consistently below uniform; gap widens at low bits)");
    Ok(())
}
