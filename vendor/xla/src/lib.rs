//! Offline stand-in for the `xla` crate (PJRT C-API bindings).
//!
//! The real crate links libxla_extension, which cannot be fetched in
//! this container. This shim keeps the same API surface the repo uses:
//!
//! * host-side [`Literal`] construction/reshape/readback works fully,
//!   so pure-Rust tests and literal plumbing run green;
//! * anything that needs the actual XLA runtime (`compile`, `execute`,
//!   `read_npy`) returns a descriptive [`Error`] — every test that
//!   depends on compiled artifacts already skips when the artifacts are
//!   absent, which is always the case without the real backend.
//!
//! Swap this path dependency for the real `xla` crate to execute the
//! AOT artifacts; no call-site changes are needed.

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error` (string-backed here).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unsupported(what: &str) -> Error {
    Error(format!(
        "{what} requires the real XLA/PJRT runtime, which is unavailable in this \
         offline build (vendor/xla stub); link the real xla crate to execute artifacts"
    ))
}

/// Element types the repo constructs literals with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    S32,
    U32,
}

/// Storage for a host literal.
#[doc(hidden)]
#[derive(Clone, Debug, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::U32(v) => v.len(),
        }
    }

    fn ty(&self) -> PrimitiveType {
        match self {
            Data::F32(_) => PrimitiveType::F32,
            Data::I32(_) => PrimitiveType::S32,
            Data::U32(_) => PrimitiveType::U32,
        }
    }
}

/// Host-side element types storable in a [`Literal`].
pub trait NativeType: Copy {
    #[doc(hidden)]
    fn to_data(v: Vec<Self>) -> Data;
    #[doc(hidden)]
    fn from_data(d: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn to_data(v: Vec<Self>) -> Data {
        Data::F32(v)
    }
    fn from_data(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn to_data(v: Vec<Self>) -> Data {
        Data::I32(v)
    }
    fn from_data(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for u32 {
    fn to_data(v: Vec<Self>) -> Data {
        Data::U32(v)
    }
    fn from_data(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::U32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// A host tensor literal (values + shape).
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            data: T::to_data(data.to_vec()),
            dims: vec![data.len() as i64],
        }
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { data: T::to_data(vec![v]), dims: vec![] }
    }

    /// Reshape to `dims` (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let numel: i64 = dims.iter().product();
        if numel as usize != self.data.len() {
            return Err(Error(format!(
                "reshape {:?} ({numel} elems) incompatible with {} elems",
                dims,
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    pub fn shape(&self) -> &[i64] {
        &self.dims
    }

    /// Read the values back as a host vector of `T`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::from_data(&self.data)
            .ok_or_else(|| Error(format!("to_vec: literal holds {:?}", self.data.ty())))
    }

    /// Cast elements to another primitive type.
    pub fn convert(&self, ty: PrimitiveType) -> Result<Literal> {
        let data = match (&self.data, ty) {
            (Data::F32(v), PrimitiveType::F32) => Data::F32(v.clone()),
            (Data::I32(v), PrimitiveType::S32) => Data::I32(v.clone()),
            (Data::U32(v), PrimitiveType::U32) => Data::U32(v.clone()),
            (Data::I32(v), PrimitiveType::U32) => Data::U32(v.iter().map(|&x| x as u32).collect()),
            (Data::U32(v), PrimitiveType::S32) => Data::I32(v.iter().map(|&x| x as i32).collect()),
            (Data::I32(v), PrimitiveType::F32) => Data::F32(v.iter().map(|&x| x as f32).collect()),
            (Data::U32(v), PrimitiveType::F32) => Data::F32(v.iter().map(|&x| x as f32).collect()),
            (Data::F32(v), PrimitiveType::S32) => Data::I32(v.iter().map(|&x| x as i32).collect()),
            (Data::F32(v), PrimitiveType::U32) => Data::U32(v.iter().map(|&x| x as u32).collect()),
        };
        Ok(Literal { data, dims: self.dims.clone() })
    }

    /// Flatten a tuple literal. Stub literals are never tuples, and the
    /// only caller feeds this from `execute`, which errors first.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unsupported("to_tuple (tuple literals)"))
    }
}

/// Raw-byte deserialization (`.npy` fixtures). Runtime-only in the
/// real crate; the golden-fixture tests skip when fixtures are absent.
pub trait FromRawBytes: Sized {
    fn read_npy<P: AsRef<Path>>(path: P, ctx: &()) -> Result<Self>;
}

impl FromRawBytes for Literal {
    fn read_npy<P: AsRef<Path>>(_path: P, _ctx: &()) -> Result<Self> {
        Err(unsupported("read_npy"))
    }
}

/// Parsed HLO module (opaque in the stub; retains the source text).
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    /// Read an HLO text artifact. File I/O errors surface faithfully so
    /// missing artifacts produce the usual "No such file" context.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// An XLA computation wrapping a module proto.
pub struct XlaComputation {
    #[allow(dead_code)]
    _p: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _p: () }
    }
}

/// PJRT client handle. Construction succeeds (host-side plumbing and
/// artifact-free tests need it); compilation reports the stub.
pub struct PjRtClient {
    _p: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _p: () })
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu (vendor/xla, no PJRT)".to_string()
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unsupported("compile"))
    }
}

/// Compiled executable handle (never constructible in the stub).
pub struct PjRtLoadedExecutable {
    _p: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unsupported("execute"))
    }
}

/// Device buffer handle (never constructible in the stub).
pub struct PjRtBuffer {
    _p: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unsupported("to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.shape(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn convert_casts() {
        let l = Literal::vec1(&[1i32, -1]);
        let u = l.convert(PrimitiveType::U32).unwrap();
        assert_eq!(u.to_vec::<u32>().unwrap(), vec![1, u32::MAX]);
        let s = Literal::scalar(2.5f32);
        assert_eq!(s.element_count(), 1);
        assert_eq!(s.shape().len(), 0);
    }

    #[test]
    fn runtime_paths_error_cleanly() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.platform_name().contains("stub"));
        assert!(c.compile(&XlaComputation::from_proto(
            &HloModuleProto { text: String::new() }
        ))
        .is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo.txt").is_err());
    }
}
