//! Offline stand-in for the `anyhow` crate.
//!
//! The container this repo builds in has no crates.io access, so the
//! small slice of `anyhow` the codebase uses is vendored here with the
//! same names and semantics: [`Error`], [`Result`], the [`Context`]
//! extension trait, and the `anyhow!` / `bail!` / `ensure!` macros.
//! Context is flattened into the message chain ("outer: inner"), which
//! is all the callers rely on.

use std::error::Error as StdError;
use std::fmt;

/// A flexible, context-carrying error (string-backed in this shim).
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        Error { msg: format!("{c}: {}", self.msg), source: self.source }
    }

    /// The root cause, when this error wraps a std error.
    pub fn source(&self) -> Option<&(dyn StdError + 'static)> {
        self.source.as_deref().map(|e| e as &(dyn StdError + 'static))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Mirrors anyhow: any std error converts into `Error`. (`Error` itself
// deliberately does not implement `std::error::Error`, which keeps this
// blanket impl coherent next to the reflexive `From<T> for T`.)
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting the error to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod ext {
    use super::*;

    /// Anything that can absorb a context message into an [`Error`].
    pub trait ErrExt {
        fn ext_context<C: fmt::Display>(self, c: C) -> Error;
    }

    impl<E: StdError + Send + Sync + 'static> ErrExt for E {
        fn ext_context<C: fmt::Display>(self, c: C) -> Error {
            Error::from(self).context(c)
        }
    }

    impl ErrExt for Error {
        fn ext_context<C: fmt::Display>(self, c: C) -> Error {
            self.context(c)
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` (any error convertible to [`Error`]) and `Option`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: ext::ErrExt> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| e.ext_context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.ext_context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<u32> {
        let v: u32 = s.parse().context("parsing number")?;
        ensure!(v < 100, "value {v} too large");
        Ok(v)
    }

    #[test]
    fn conversion_and_context_chain() {
        assert_eq!(parse("42").unwrap(), 42);
        let e = parse("nope").unwrap_err();
        assert!(e.to_string().starts_with("parsing number: "), "{e}");
        assert!(e.source().is_some());
        let e = parse("123").unwrap_err();
        assert_eq!(e.to_string(), "value 123 too large");
    }

    #[test]
    fn option_context_and_macros() {
        let none: Option<u8> = None;
        let e = none.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
        let e2: Error = anyhow!("x = {}", 7);
        assert_eq!(e2.to_string(), "x = 7");
        let with: Result<u8> = None.with_context(|| format!("lazy {}", 1));
        assert_eq!(with.unwrap_err().to_string(), "lazy 1");
    }

    #[test]
    fn bail_returns_error() {
        fn f(flag: bool) -> Result<()> {
            if flag {
                bail!("flagged {}", 1);
            }
            Ok(())
        }
        assert!(f(false).is_ok());
        assert_eq!(f(true).unwrap_err().to_string(), "flagged 1");
    }
}
